"""LM serving engine: compiled prefill + decode programs behind a
continuous-batching slot scheduler.

This is the small-scale executable counterpart of launch/build.build_serve
(which produces the production-mesh programs).  ServeEngine runs real tokens
on the local device(s) through the unified serve path (serve/base.py):

  * prefill AND decode both lower through the model-agnostic engine IR
    (compiler.lower_transformer) into programs cached in the keyed
    ProgramCache -- the same compile -> cache -> schedule pipeline
    CNNServeEngine uses -- keyed by (ArchConfig, EngineConfig,
    calibration-id) with distinct prefill/decode variants.  With
    calibration token batches and a w8a8 engine BOTH programs are
    static-int8 from ONE calibration run (compiler.calibrate_lm): every
    projection GEMM -- including every decode-step GEMM, the steady-state
    serving path -- consumes activations pre-quantized at compile-time
    scales instead of re-quantizing per token.
  * the compiled prefill program fills the decode KV cache (each AttnOp
    deposits its roped-k/v pair), and the compiled decode program IS the
    cache recurrence (AttnOp `update` mode): the decode burst executes it
    jit-once with the cache donated, exactly like the eager path it
    replaces.
  * requests queue in the shared SlotScheduler (serve/base.py): `submit()`
    enqueues (prompt, max_new_tokens); `run()` serves the whole queue with
    B fixed decode slots, refilling finished slots from the queue between
    decode bursts (continuous batching).  Prompts left-pad to one fixed
    prefill width, so a request's tokens depend only on its own padded
    slot row: with `prefill_len` pinned at construction, arrival order and
    batch composition cannot change its output (the order-invariance
    property test pins that; see run() on the unset-width default).

With `mesh=` the engine serves tensor-parallel (serve/mesh_exec.py):
projection weights shard over the mesh's "model" axis at whole-head
granularity, the KV cache replicates, and every decode-burst GEMM runs
sharded -- bit-identical to single-device execution (the sharded-parity
property test pins it).  Decode dispatch is async: bursts keep emitted
token columns on device and the host syncs only at response edges (a
request completing), never per step.

With `kv_layout="paged"` the engine's global-attention KV state lives in a
shared block pool (T.paged_cache_schema) behind ONE block table: a request
holds exactly ceil((prompt + max_new_tokens) / page_size) blocks from a
BlockAllocator (serve/kv_alloc.py), and admission gates on FREE BLOCKS, not
worst-case slot envelopes -- so sustainable concurrency at fixed memory
follows the measured request footprint (the paper's bandwidth thesis
applied to cache capacity).  Paged decode is bit-identical to dense: the
gather is a pure copy and masked positions exp-underflow to exactly zero.

With `prefix_sharing=True` (paged only, pinned `prefill_len`) requests that
share a page-aligned prompt PREFIX share the physical KV blocks holding it:
a hash-chain prefix index over page-sized token chunks (keyed by the
engine's (calibration-id, page_size)) maps each admitted request's padded
row to the longest already-cached prefix, the allocator refcounts those
blocks instead of allocating new ones (`BlockAllocator.share`), and
prefill runs a CHUNK program over only the unshared tail
(`compiler.prefill_from`): shared pages are read-only (stores below a
row's matched length drop -- copy-on-write at the page boundary), decode
writes always land in freshly owned pages, and release decrements
refcounts, freeing a block only when its last owner leaves.  The chunk
program ALWAYS round-trips attended k/v through the cache dtype (it
stores the fresh tail, then attends the gathered view), so a request's
token ids are a pure function of its padded row -- invariant to where
the page-aligned split falls and to index warmth.  When the compute
dtype equals the cache dtype (quant="none", bf16 cache: store-cast is
the identity) that makes shared serving bit-identical to non-shared
serving; with f32 attention inputs (static int8 programs) or an int8 KV
cache, non-shared PREFILL attends pre-roundtrip values the shared prefix
cannot reproduce, so sharing stays deterministic and split-invariant but
may round differently than the isolated engine.  Archs with local (ring)
attention layers fall back to whole-prompt prefill (the dense ring has no
page boundary to share at); `stats()["prefix_sharing"]` records the
blocker.

With `draft_len=k` decode runs SPECULATIVE bursts: each step teacher-forces
the current token plus k self-speculative n-gram drafts (no second model)
through ONE [B, 1+k]-wide DecodeStep execution (`execute_verify`), accepts
the longest greedy-consistent prefix, and commits only accepted positions
(`commit_decode_kv`) -- rejected drafts never touch the cache, so emitted
ids match one-token greedy decode token-for-token while each burst can
commit multiple tokens.

SSM / MoE mixers and the audio encoder-decoder stay eager: `stats()`
reports the exact `lowering_blockers` instead of silently falling back.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compiler
from repro.compiler import executor as ex
from repro.core import engine as eng_lib
from repro.core.config import ArchConfig, EngineConfig
from repro.models import params as prm
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models.params import is_spec
from repro.serve.base import (ProgramServeBase, SlotScheduler,
                              calibration_digest)
from repro.serve.kv_alloc import BlockAllocator
from repro.serve.program_cache import ProgramCache

_LM = "lm"                            # the scheduler's single slot group


class PrefixIndex:
    """Hash-chain index over page-aligned token chunks -> physical blocks.

    Each node keys one page-sized chunk of a padded prompt by the CHAIN of
    chunks before it (the node key is the tuple of chunk byte-strings from
    the root), so `match()` walks the longest indexed prefix in O(pages)
    dict lookups -- a radix tree flattened into a dict.  The index holds
    its OWN refcount on every registered block (`alloc.share`), so a block
    stays warm for future matches after its last request leaves; under
    allocation pressure `evict_for()` drops leaf nodes nobody but the
    index references (refcount == 1), children before parents.

    `key` records the (calibration-id, page_size) the index is valid for:
    cached KV bits are a function of both, so an engine never matches
    pages produced under a different quantization or page geometry.
    """

    def __init__(self, page_size: int, alloc: BlockAllocator,
                 key=None):
        self.page = int(page_size)
        self.alloc = alloc
        self.key = key
        # chain-key tuple -> {"block": id, "children": set of chain keys}
        self._nodes: Dict[tuple, dict] = {}
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._nodes)

    def _chunk(self, row: np.ndarray, i: int) -> bytes:
        return np.ascontiguousarray(
            row[i * self.page:(i + 1) * self.page], np.int32).tobytes()

    def match(self, row: np.ndarray, max_pages: int) -> List[int]:
        """Block ids of the longest indexed page-aligned prefix of `row`
        (the PADDED prompt -- pad tokens are ordinary context, so cached
        bits are a function of the padded row).  Pure: no refcounts move;
        callers `alloc.share()` the result when they bind it."""
        key, blocks = (), []
        for i in range(min(max_pages, len(row) // self.page)):
            nkey = key + (self._chunk(row, i),)
            node = self._nodes.get(nkey)
            if node is None:
                break
            blocks.append(node["block"])
            key = nkey
        return blocks

    def register(self, row: np.ndarray, blocks: List[int],
                 pages: int) -> int:
        """Index the first `pages` chunks of `row` against `blocks`.  Pages
        already indexed keep their existing node (the caller matched them,
        so blocks[i] IS that node's block); new nodes take an index-owned
        refcount.  Returns how many new nodes were added."""
        key, added = (), 0
        for i in range(min(pages, len(row) // self.page, len(blocks))):
            nkey = key + (self._chunk(row, i),)
            if nkey not in self._nodes:
                self.alloc.share([blocks[i]])        # the index's own ref
                self._nodes[nkey] = {"block": blocks[i], "children": set()}
                if key in self._nodes:
                    self._nodes[key]["children"].add(nkey)
                added += 1
            key = nkey
        return added

    def held_only(self) -> int:
        """Blocks the index alone still references (refcount == 1) --
        reclaimable by eviction, and excluded from 'active' occupancy."""
        return sum(1 for n in self._nodes.values()
                   if self.alloc.refcount(n["block"]) == 1)

    def evict_for(self, need: int, protected=frozenset()) -> int:
        """Free index-only leaf nodes (children first) until `need` blocks
        are free or nothing evictable remains.  `protected` blocks (a
        candidate request's matched chain) are never victims.  Returns the
        number of nodes evicted."""
        evicted = 0
        while self.alloc.free_blocks < need:
            victim = next(
                (k for k, n in self._nodes.items()
                 if not n["children"]
                 and n["block"] not in protected
                 and self.alloc.refcount(n["block"]) == 1), None)
            if victim is None:
                break
            node = self._nodes.pop(victim)
            parent = victim[:-1]
            if parent in self._nodes:
                self._nodes[parent]["children"].discard(victim)
            self.alloc.free([node["block"]])
            self.evictions += 1
            evicted += 1
        return evicted

    def reset(self) -> None:
        """Drop every node and its ref -- for when the pool backing the
        indexed bits is discarded (the chains would otherwise resolve to
        blocks whose contents no longer exist)."""
        for node in self._nodes.values():
            self.alloc.free([node["block"]])
        self._nodes.clear()


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # [L] int32
    max_new_tokens: int = 16
    out_tokens: Optional[list] = None


@dataclasses.dataclass(frozen=True)
class SubmitRejection:
    """Structured submit() rejection (queue-level backpressure, NOT an
    exception): the request cannot be served by this engine configuration.
    Falsy, so `if ticket:` keeps working for accepted submissions."""
    reason: str                     # "over_length" | "over_capacity"
    detail: str
    prompt_len: int
    max_new_tokens: int

    def __bool__(self) -> bool:
        return False


@dataclasses.dataclass
class LMServeStats:
    """Continuous-batching counters across run() calls."""
    requests: int = 0
    prefill_calls: int = 0            # batched prefill executions
    decode_steps: int = 0             # decode program/burst steps
    active_slot_steps: int = 0        # slot-steps that served a request
    slot_refills: int = 0             # slots reused after a finished request
    rejected_requests: int = 0        # structured submit() rejections
    spec_steps: int = 0               # speculative verify bursts
    spec_slot_steps: int = 0          # slot-bursts (active slots x bursts)
    drafted_tokens: int = 0           # draft tokens eligible for acceptance
    accepted_drafts: int = 0          # drafts that matched greedy decode
    committed_tokens: int = 0         # tokens emitted by spec bursts
    prefill_tokens_computed: int = 0  # prompt tokens actually run through
                                      # a prefill program (tail-only under
                                      # prefix sharing)
    prefix_hits: int = 0              # requests that matched >= 1 page
    prefix_misses: int = 0            # requests that matched nothing
    prefix_shared_blocks: int = 0     # blocks joined via refcount bumps
    batch: int = 0

    @property
    def slot_occupancy(self) -> float:
        total = self.decode_steps * max(self.batch, 1)
        return self.active_slot_steps / total if total else 0.0

    @property
    def refill_rate(self) -> float:
        """Fraction of requests admitted by refilling a finished slot
        mid-run rather than by the initial batch fill."""
        return self.slot_refills / self.requests if self.requests else 0.0

    @property
    def accepted_draft_rate(self) -> float:
        """Fraction of eligible draft tokens that matched greedy decode."""
        return (self.accepted_drafts / self.drafted_tokens
                if self.drafted_tokens else 0.0)

    @property
    def tokens_per_burst(self) -> float:
        """Mean tokens committed per slot per verify burst, in [1, 1+k]
        (per SLOT-burst, not per batch step: dividing by bursts alone
        would credit batch width as speculation win)."""
        return (self.committed_tokens / self.spec_slot_steps
                if self.spec_slot_steps else 0.0)


class ServeEngine(ProgramServeBase):
    def __init__(self, arch: ArchConfig, params, eng: EngineConfig,
                 batch_size: int = 4, max_seq: int = 256,
                 calib_batches: Optional[Sequence] = None,
                 calibrator: str = "absmax",
                 granularity: str = "per_tensor",
                 cache: Optional[ProgramCache] = None,
                 cache_capacity: int = 4, scheduled: bool = True,
                 schedule_policy: str = "asap",
                 compile_prefill: bool = True,
                 compile_decode: bool = True,
                 decode_burst: int = 4,
                 prefill_len: Optional[int] = None,
                 mesh=None,
                 kv_layout: str = "dense",
                 page_size: int = 8,
                 kv_blocks: Optional[int] = None,
                 draft_len: int = 0,
                 prefix_sharing: bool = False):
        super().__init__(eng, cache_capacity=cache_capacity,
                         scheduled=scheduled, cache=cache,
                         schedule_policy=schedule_policy, mesh=mesh)
        self.arch = arch
        self.batch, self.max_seq = batch_size, max_seq
        self.decode_burst = max(1, decode_burst)
        self.prefill_len = prefill_len
        self._float_params = params
        self.params = eng_lib.quantize_params(params, eng)
        self.is_audio = arch.family == "audio"
        # mesh= places the param tree tensor-parallel over the "model"
        # axis (whole-head granularity; see serve/mesh_exec.py) -- decode
        # bursts then run their projection GEMMs sharded, bit-identical
        # to single-device
        self.tp_placement = None
        if self.mexec is not None:
            if self.is_audio:
                self.params = self.mexec.replicate(self.params)
            else:
                self.params, self.tp_placement = \
                    self.mexec.place_lm_params(arch, self.params)
        mod = W if self.is_audio else T
        self.mod = mod
        # Prefill/decode compile through the engine IR when the arch
        # lowers; SSM / MoE / audio archs fall back to the eager path and
        # stats() carries the blockers.
        lowerable = not self.is_audio and compiler.can_lower(arch)
        self.compiled = compile_prefill and lowerable
        self.compiled_decode = compile_decode and lowerable
        # -- block-paged KV cache + speculative decode configuration ------
        if kv_layout not in ("dense", "paged"):
            raise ValueError(f"kv_layout must be 'dense' or 'paged', got "
                             f"{kv_layout!r}")
        self.kv_layout = kv_layout
        self.paged = kv_layout == "paged"
        self.draft_len = int(draft_len)
        self.page_size = int(page_size)
        if self.draft_len < 0:
            raise ValueError(f"draft_len must be >= 0, got {draft_len}")
        if self.paged or self.draft_len:
            if not (self.compiled and self.compiled_decode):
                blockers = self.lowering_blockers() or ["compile_* disabled"]
                raise ValueError(
                    "paged KV / speculative decode need the compiled "
                    f"prefill+decode programs ({'; '.join(blockers)})")
            if self.mexec is not None:
                raise ValueError("paged KV / speculative decode are "
                                 "single-device paths (mesh=None)")
        self.alloc: Optional[BlockAllocator] = None
        if self.paged:
            if self.page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {page_size}")
            # round max_seq UP to a page multiple: the gathered view is
            # then shape-identical to the dense cache (bit-identity)
            self.max_seq = T.num_pages(self.max_seq,
                                       self.page_size) * self.page_size
            self.kv_pages = T.num_pages(self.max_seq, self.page_size)
            total = (int(kv_blocks) if kv_blocks is not None
                     else batch_size * self.kv_pages)
            self.alloc = BlockAllocator(total)
            # host mirror of cache["tables"]; the POSITIVE sentinel `total`
            # (one past the pool) makes unallocated-page writes drop --
            # negative sentinels would WRAP in a JAX scatter
            self._host_tables = np.full((batch_size, self.kv_pages), total,
                                        np.int32)
            self._slot_blocks: List[List[int]] = [[] for _ in
                                                  range(batch_size)]
        # -- prefix sharing (refcounted copy-on-write blocks) --------------
        self.prefix_sharing = bool(prefix_sharing)
        self.prefix_sharing_blockers: List[str] = []
        self.prefix_index: Optional[PrefixIndex] = None
        if self.prefix_sharing:
            if not self.paged:
                raise ValueError("prefix_sharing requires kv_layout='paged' "
                                 "(it shares physical KV pages)")
            if prefill_len is None:
                raise ValueError(
                    "prefix_sharing requires a pinned prefill_len: cached "
                    "KV bits are a function of the PADDED row, so the pad "
                    "width must not depend on queue composition")
            if any(arch.layer_kind(i) == "local"
                   for i in range(arch.n_layers)):
                # documented fallback (not an error): the dense ring KV of
                # local layers has no page boundary to share at, so these
                # archs serve with private whole-prompt prefill
                self.prefix_sharing_blockers.append(
                    "local attention layers (dense ring KV has no page "
                    "boundary)")
                self.prefix_sharing = False
        self._paged_jit = None        # (program, jitted paged prefill+merge)
        self._chunk_jit = None        # (program, jitted chunk prefill)
        self._pool_cache = None       # paged pool persisted across runs
        self._spec_jit = None         # (program, jitted verify+commit step)
        # calibration only feeds the compiled static programs; skip the
        # (whole-param-tree) digest when both paths stay eager.  w4a8
        # shares w8a8's activation calibration (same float graph, same
        # scales) but the digest carries weight_mode so w4 and w8 programs
        # key distinct ProgramCache lines.
        batches = (list(calib_batches)
                   if calib_batches is not None
                   and eng.quant in ("w8a8", "w4a8")
                   and (self.compiled or self.compiled_decode) else None)
        self.calib_batches = batches
        self.calib_id = (calibration_digest(
                             batches, params, calibrator, granularity,
                             weight_mode=eng_lib.weight_mode(eng))
                         if batches is not None else None)
        self.calibrator = calibrator
        self.granularity = granularity
        self._scales = None           # one calibration run, both programs
        if self.prefix_sharing:
            # the index is only valid for KV bits produced under THIS
            # quantization + page geometry, so it carries both as its key
            self.prefix_index = PrefixIndex(self.page_size, self.alloc,
                                            key=(self.calib_id,
                                                 self.page_size))
        self._sched = SlotScheduler(batch_size)
        self.serve_stats = LMServeStats(batch=batch_size)

        def _prefill(params, cache, batch):
            return mod.prefill(params, cache, batch, arch, eng)

        def _decode(params, cache, tokens):
            return mod.decode(params, cache, tokens, arch, eng)

        self.jprefill = jax.jit(_prefill, donate_argnums=(1,))
        self.jdecode = jax.jit(_decode, donate_argnums=(1,))

        def _merge(old, new, mask):
            """Scatter refilled slots' prefill state into the live cache:
            per-slot row select on every [B, ...] leaf, per-slot pos."""
            def sel(o, n):
                m = mask.reshape((mask.shape[0],) + (1,) * (o.ndim - 1))
                return jnp.where(m, n.astype(o.dtype), o)
            layers = jax.tree_util.tree_map(sel, old["layers"],
                                            new["layers"])
            pos = jnp.where(mask, jnp.asarray(new["pos"], jnp.int32),
                            jnp.asarray(old["pos"], jnp.int32))
            return {"layers": layers, "pos": pos}

        self.jmerge = jax.jit(_merge, donate_argnums=(0, 1))

    # -- compiled programs (the unified serve path) --------------------------

    def lowering_blockers(self) -> List[str]:
        """Why this arch's programs fell back to eager ([] = compiled)."""
        if self.is_audio:
            return ["encoder-decoder (audio)"]
        return compiler.lowering_blockers(self.arch)

    def _lm_scales(self):
        """The shared calibration run: one execution of the calibration
        batches quantizes prefill AND decode (graph node ids line up)."""
        if self._scales is None:
            self._scales = compiler.calibrate_lm(
                self.arch, self._float_params, self.calib_batches,
                method=self.calibrator, granularity=self.granularity)
        return self._scales

    def _prefill_key(self):
        return self._program_key(self.arch, self.calib_id, tag="prefill")

    def _decode_key(self):
        # page size and draft length ride the key: paged/dense x draft
        # variants hold DISTINCT ProgramCache lines (and jitted traces --
        # a [B, 1+k] verify trace is not a [B, 1] decode trace)
        tag = ("decode"
               + (f":p{self.page_size}" if self.paged else "")
               + (f":k{self.draft_len}" if self.draft_len else ""))
        return self._program_key(self.arch, self.calib_id, tag=tag)

    def _chunk_key(self):
        # the chunk (prefix-sharing partial-prefill) program variant; page
        # size rides the tag like the decode key's
        return self._program_key(self.arch, self.calib_id,
                                 tag=f"chunk:p{self.page_size}")

    def _compile_mode(self, mode: str) -> ex.Program:
        page = (self.page_size
                if (self.paged and mode in ("decode", "chunk")) else 0)
        if self.calib_batches is None:
            return compiler.compile_lm(self.arch, scheduled=self.scheduled,
                                       policy=self.schedule_policy,
                                       mode=mode, page_size=page)
        return compiler.compile_lm(self.arch, scales=self._lm_scales(),
                                   scheduled=self.scheduled,
                                   policy=self.schedule_policy, mode=mode,
                                   granularity=self.granularity,
                                   page_size=page)

    def prefill_program(self) -> ex.Program:
        """The compiled prefill program: ProgramCache hit, or compile."""
        return self._cached_program(self._prefill_key(),
                                    lambda: self._compile_mode("prefill"))

    def decode_program(self) -> ex.Program:
        """The compiled DecodeStep program: ProgramCache hit, or compile."""
        return self._cached_program(self._decode_key(),
                                    lambda: self._compile_mode("decode"))

    def chunk_program(self) -> ex.Program:
        """The compiled chunk (prefill-tail) program: ProgramCache hit, or
        compile.  Used for EVERY prefill when prefix sharing is on --
        start=0 on an index miss -- so logits are invariant to where the
        page-aligned split falls (see compiler.prefill_from)."""
        return self._cached_program(self._chunk_key(),
                                    lambda: self._compile_mode("chunk"))

    def _run_program_prefill(self, program: ex.Program, params, cache,
                             batch):
        """Execute the prefill program and write the collected per-layer
        (k, v) pairs into the decode cache -- the compiled counterpart of
        `T.prefill` (bit-identical cache layout)."""
        tokens = batch["tokens"]
        kvs: Dict[int, tuple] = {}
        logits = ex.execute(program, params, tokens, self.eng, collect=kvs)
        new_layers = []
        for i in range(self.arch.n_layers):
            entry = cache["layers"][i]
            k, v = kvs[i]
            if self.arch.layer_kind(i) == "local":
                w = entry["k"].shape[1]
                entry = T._kv_store(entry, k[:, -w:], v[:, -w:], 0, self.eng)
            else:
                entry = T._kv_store(entry, k, v, 0, self.eng)
            new_layers.append(entry)
        return logits, {"layers": new_layers,
                        "pos": jnp.asarray(tokens.shape[1], jnp.int32)}

    def _prefill_exec(self):
        """The jitted prefill executable: the eager path, or the cached
        program's (traced once per cached program; stats accrue per call)."""
        if not self.compiled:
            return self.jprefill
        program = self.prefill_program()
        return self._jitted_for(
            self._prefill_key(), program,
            lambda prog: jax.jit(
                functools.partial(self._run_program_prefill, prog),
                donate_argnums=(1,)))

    def _decode_exec(self):
        """The jitted decode-step executable: the compiled DecodeStep
        program from the ProgramCache (jit-once, cache donated), or the
        eager `T.decode` for fallback archs."""
        if not self.compiled_decode:
            return self.jdecode
        program = self.decode_program()
        return self._jitted_for(
            self._decode_key(), program,
            lambda prog: jax.jit(
                lambda params, cache, tokens: ex.execute_decode(
                    prog, params, cache, tokens, self.eng),
                donate_argnums=(1,)))

    def _run_paged_prefill(self, program: ex.Program, params, cache, batch,
                           mask):
        """Execute the prefill program and scatter the refilled slots'
        collected (k, v) spans through the block table into the live paged
        cache -- prefill + merge fused in one jitted step (`mask` [B] gates
        rows; foreign rows' writes drop via the table sentinel)."""
        tokens = batch["tokens"]
        kvs: Dict[int, tuple] = {}
        logits = ex.execute(program, params, tokens, self.eng, collect=kvs)
        sel_mask = mask

        def sel(o, n):
            m = sel_mask.reshape((sel_mask.shape[0],) + (1,) * (o.ndim - 1))
            return jnp.where(m, n.astype(o.dtype), o)

        layers = []
        for i in range(self.arch.n_layers):
            entry = cache["layers"][i]
            k, v = kvs[i]
            if self.arch.layer_kind(i) == "local":
                w = entry["k"].shape[1]
                fresh = jax.tree_util.tree_map(jnp.zeros_like, entry)
                fresh = T._kv_store(fresh, k[:, -w:], v[:, -w:], 0, self.eng)
                entry = jax.tree_util.tree_map(sel, entry, fresh)
            else:
                entry = T._paged_prefill_store(entry, k, v, cache["tables"],
                                               mask, self.eng,
                                               self.page_size)
            layers.append(entry)
        pos = jnp.where(mask, jnp.asarray(tokens.shape[1], jnp.int32),
                        jnp.asarray(cache["pos"], jnp.int32))
        return logits, {"layers": layers, "tables": cache["tables"],
                        "pos": pos}

    def _paged_prefill_exec(self):
        """Jitted paged prefill+merge (traced once per cached program)."""
        program = self.prefill_program()
        if self._paged_jit is None or self._paged_jit[0] is not program:
            fn = jax.jit(functools.partial(self._run_paged_prefill, program),
                         donate_argnums=(1,))
            self._paged_jit = (program, fn)
        return self._paged_jit[1]

    def _chunk_prefill_exec(self):
        """Jitted chunk prefill (one trace per tail width; `start` and the
        per-row match lengths are traced operands, so every width-T wave
        shares one executable regardless of which pages matched)."""
        program = self.chunk_program()
        if self._chunk_jit is None or self._chunk_jit[0] is not program:
            def run(params, cache, tokens, start, row_starts, mask):
                return ex.prefill_from(program, params, cache, tokens,
                                       self.eng, start=start,
                                       row_starts=row_starts, mask=mask)
            self._chunk_jit = (program, jax.jit(run, donate_argnums=(1,)))
        return self._chunk_jit[1]

    def _shared_prefill(self, cache, toks: np.ndarray, mask: np.ndarray,
                        matched: np.ndarray):
        """One admission wave's chunked prefill: run the chunk program on
        the tail past the wave's SHORTEST match.  Rows whose own match
        extends further recompute those positions (bit-identical to the
        shared pages' content; their stores drop below `matched[row]`), so
        one fused wave serves mixed match lengths.  Accounts the tokens
        actually computed."""
        plen = toks.shape[1]
        admitted = matched[mask]
        start = int(admitted.min()) if admitted.size else 0
        tail = toks[:, start:]
        logits, cache = self._chunk_prefill_exec()(
            self.params, cache, jnp.asarray(tail),
            jnp.asarray(start, jnp.int32),
            jnp.asarray(matched, jnp.int32), jnp.asarray(mask))
        self.serve_stats.prefill_tokens_computed += \
            int(mask.sum()) * (plen - start)
        return logits, cache

    def _spec_exec(self):
        """The jitted speculative step: ONE [B, 1+k]-wide verify execution,
        greedy acceptance, masked commit -- a single device round-trip per
        burst, cache donated like the plain decode step."""
        program = self.decode_program()
        if self._spec_jit is None or self._spec_jit[0] is not program:
            def step(params, cache, tokens, cap):
                # tokens [B, W]: column 0 is each slot's current token, the
                # rest are n-gram drafts; cap [B] bounds acceptance (0 for
                # idle slots, so their rows can never commit)
                logits, kvs = ex.execute_verify(program, params, cache,
                                                tokens, self.eng)
                g = jnp.argmax(logits, -1).astype(jnp.int32)   # [B, W]
                match = (tokens[:, 1:] == g[:, :-1]).astype(jnp.int32)
                accept = jnp.minimum(
                    1 + jnp.sum(jnp.cumprod(match, axis=1), axis=1), cap)
                cache = ex.commit_decode_kv(program, cache, kvs, accept,
                                            self.eng)
                idx = jnp.maximum(accept - 1, 0)
                nxt = jnp.take_along_axis(g, idx[:, None], axis=1)[:, 0]
                return accept, nxt, cache
            self._spec_jit = (program, jax.jit(step, donate_argnums=(1,)))
        return self._spec_jit[1]

    # -- request queue / continuous batching ---------------------------------

    def _empty_cache(self):
        if self.is_audio:
            cs = W.whisper_cache_schema(self.arch, self.batch, self.max_seq,
                                        self.eng)
        elif self.paged:
            cs = T.paged_cache_schema(self.arch, self.batch, self.max_seq,
                                      self.eng, self.page_size,
                                      num_blocks=self.alloc.num_blocks)
        else:
            cs = T.cache_schema(self.arch, self.batch, self.max_seq, self.eng)
        cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), cs, is_leaf=is_spec)
        if self.paged:
            cache["tables"] = jnp.asarray(self._host_tables)
        if self.mexec is not None:
            cache = self.mexec.replicate(cache)   # KV cache stays replicated
        return cache

    def _run_cache(self, B: int):
        """The cache a run() starts from.  Plain serving builds a fresh
        zeroed pool per run; prefix sharing must NOT -- the index maps
        token prefixes to block ids whose *contents* live in the pool, so
        the pool persists across runs (stashed at run exit, reclaimed
        here).  If the pool is gone (first run, or a prior run aborted
        mid-donation) any surviving index nodes point at bits that no
        longer exist, so the index resets rather than serve zeros."""
        if self.prefix_sharing:
            if self._pool_cache is not None:
                cache = self._pool_cache
                self._pool_cache = None   # donated into this run's execs
            else:
                if len(self.prefix_index):
                    self.prefix_index.reset()
                cache = self._empty_cache()
        else:
            cache = self._empty_cache()
        cache["pos"] = jnp.zeros((B,), jnp.int32)   # per-slot positions
        return cache

    def submit(self, prompt, max_new_tokens: int = 16):
        """Queue one prompt; returns its ticket (the key of its decoded
        token ids in run()'s results), or a falsy `SubmitRejection` when
        the request cannot be served (over max_seq, or over the paged
        pool's total capacity) -- queue-level backpressure instead of an
        exception, so callers can shed load without try/except."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens} (a "
                "0-token request would never own its slot and be dropped)")
        if len(prompt) + max_new_tokens > self.max_seq:
            self.serve_stats.rejected_requests += 1
            return SubmitRejection(
                reason="over_length",
                detail=(f"prompt ({len(prompt)}) + max_new_tokens "
                        f"({max_new_tokens}) exceeds "
                        f"max_seq={self.max_seq}"),
                prompt_len=len(prompt), max_new_tokens=int(max_new_tokens))
        if self.paged:
            need = T.num_pages(len(prompt) + max_new_tokens, self.page_size)
            if need > self.alloc.num_blocks:
                self.serve_stats.rejected_requests += 1
                return SubmitRejection(
                    reason="over_capacity",
                    detail=(f"request needs {need} KV blocks but the pool "
                            f"holds {self.alloc.num_blocks} total"),
                    prompt_len=len(prompt),
                    max_new_tokens=int(max_new_tokens))
        ticket = self._sched.submit(_LM, (prompt, int(max_new_tokens)))
        self.latency.submitted(ticket)
        return ticket

    def pending(self) -> int:
        return self._sched.pending(_LM)

    def _blocks_needed(self, plen: int, mnt: int) -> int:
        """Blocks covering positions [0, padded-prompt + new tokens); the
        dense cache silently drops writes past max_seq, so cap there (the
        paged sentinel reproduces the same drop)."""
        return T.num_pages(min(plen + mnt, self.max_seq), self.page_size)

    def _padded_row(self, prompt: np.ndarray, plen: int) -> np.ndarray:
        """The left-padded token row prefix matching operates on (pad
        tokens are ordinary context, so cached KV bits are a function of
        the PADDED row, not the raw prompt)."""
        row = np.zeros(plen, np.int32)
        row[plen - len(prompt):] = prompt
        return row

    def _max_match_pages(self, plen: int) -> int:
        """Matching leaves the tail at least ONE token: prefill must run a
        non-empty span to emit the last position's logits."""
        return (plen - 1) // self.page_size

    def _fresh_needed(self, prompt: np.ndarray, plen: int, mnt: int) -> int:
        """Blocks this request must ALLOCATE (not share), given the current
        index state: total need minus its matched-prefix pages.  Shared
        pages are accounted once -- joining them costs no free blocks."""
        need = self._blocks_needed(plen, mnt)
        if not self.prefix_sharing or len(prompt) > plen:
            return need           # over-long prompts fail loudly in run()
        row = self._padded_row(np.asarray(prompt, np.int32), plen)
        m = len(self.prefix_index.match(row, self._max_match_pages(plen)))
        return need - m

    def _admit(self, nfree: int, plen: int):
        """FIFO admission: dense takes up to `nfree` queued requests; paged
        additionally gates each on free blocks, head-of-line (no
        reordering -- arrival order is the serving contract), allocating
        the request's blocks and writing its host table row.  Under prefix
        sharing the gate counts only the FRESH blocks a request needs --
        matched pages are shared, not allocated, so a wave of same-prefix
        requests admits where private allocation would backpressure."""
        if not self.paged:
            return self._sched.take(_LM, limit=nfree)
        taken, reserved = [], 0
        while len(taken) < nfree and self._sched.pending(_LM):
            prompt, mnt = self._sched.peek(_LM)[0]
            # gate on free minus what THIS wave already reserved: the
            # actual allocs happen later in _bind_blocks, so probing each
            # request against the raw free count would over-admit.  (The
            # binding's own match can only be LONGER than this probe's --
            # same-wave registrations add nodes, evictions never run
            # mid-wave -- so the reservation is an upper bound.)
            need = self._fresh_needed(prompt, plen, mnt)
            if not self.alloc.can_allocate(reserved + need):
                break                 # backpressure: wait for frees
            reserved += need
            taken.extend(self._sched.take(_LM, limit=1))
        return taken

    def _bind_blocks(self, slot: int, plen: int, mnt: int,
                     row: Optional[np.ndarray] = None) -> int:
        """Bind an admitted request's blocks into its slot's table row
        (host mirror; pushed to device at the admission edge, the only
        point where freed blocks may be reassigned).

        With prefix sharing (`row` = the padded prompt), the longest
        indexed prefix is JOINED -- refcounts bump instead of allocating
        -- and only the remaining pages come from the free list; the
        prompt's full pages are then registered so later arrivals can
        match them (including same-wave ones: the wave's prefill writes
        every admitted row's owned pages before any of them decodes).
        Returns the matched prefix length in tokens (0 without sharing)."""
        need = self._blocks_needed(plen, mnt)
        matched: List[int] = []
        if self.prefix_sharing and row is not None:
            matched = self.prefix_index.match(row,
                                              self._max_match_pages(plen))
            if matched:
                self.alloc.share(matched)
                self.serve_stats.prefix_hits += 1
                self.serve_stats.prefix_shared_blocks += len(matched)
            else:
                self.serve_stats.prefix_misses += 1
        blocks = matched + self.alloc.alloc(need - len(matched))
        self._slot_blocks[slot] = blocks
        trow = np.full(self.kv_pages, self.alloc.num_blocks, np.int32)
        trow[:need] = blocks
        self._host_tables[slot] = trow
        if self.prefix_sharing and row is not None:
            # register only pages FULLY covered by the prompt: a partial
            # last page is decode-writable, so it stays request-private
            self.prefix_index.register(row, blocks,
                                       plen // self.page_size)
        return len(matched) * self.page_size

    def _ensure_admissible(self, plen: int) -> None:
        """Called when the queue is non-empty but no slot is active and
        admission produced nothing.  Without sharing that means the pool
        itself is too small (nothing in flight will ever free blocks), so
        raise.  With sharing the prefix index may be what is holding
        blocks: this is the quiescent point -- no slot owns a table row --
        so leaf index nodes can be evicted without invalidating any bound
        table, and admission retries after eviction."""
        if not self.paged:
            return
        prompt, mnt = self._sched.peek(_LM)[0]
        need = self._blocks_needed(plen, mnt)
        if self.prefix_sharing:
            fresh = need
            protected: set = set()
            if len(prompt) <= plen:
                row = self._padded_row(np.asarray(prompt, np.int32), plen)
                mblocks = self.prefix_index.match(
                    row, self._max_match_pages(plen))
                fresh = need - len(mblocks)
                protected = set(mblocks)
            self.prefix_index.evict_for(fresh, protected=protected)
            if self.alloc.free_blocks >= fresh:
                return                # admission will succeed next pass
            raise RuntimeError(
                f"queued request needs {fresh} fresh KV blocks beyond its "
                f"shared prefix but only {self.alloc.free_blocks} of "
                f"{self.alloc.num_blocks} are free after evicting unshared "
                "prefixes; raise kv_blocks or shrink the request")
        if self.alloc.in_use == 0:
            raise RuntimeError(
                f"queued request needs {need} KV blocks "
                f"but the pool holds {self.alloc.num_blocks} "
                "total; raise kv_blocks or shrink the request")

    def _release_blocks(self, slot: int) -> None:
        """Response edge: return the slot's blocks and clear its row to the
        drop sentinel (the dead slot's in-flight writes then land nowhere,
        so a freed block reassigned at the NEXT admission edge -- after the
        cleared row is pushed -- can never be corrupted)."""
        self.alloc.free(self._slot_blocks[slot])
        self._slot_blocks[slot] = []
        self._host_tables[slot] = self.alloc.num_blocks

    def run(self) -> Dict[int, np.ndarray]:
        """Serve the queue to completion with continuous batching: prefill
        fills free slots, decode bursts advance every slot one token per
        step, and finished slots refill from the queue between bursts.
        Returns {ticket: greedy token ids}.

        Every prompt left-pads to ONE prefill width (`prefill_len`, or the
        longest queued prompt when unset); pad tokens are ordinary context
        (no pad masking, like the legacy wave path), so a request's output
        is a function of its padded row alone.  With `prefill_len` set the
        row -- and therefore the output -- is independent of arrival order
        and batch composition (the order-invariance property test); with
        it unset, prompts shorter than the queue's max see a
        queue-dependent pad width, exactly as the per-wave padding before
        them did.

        Dispatch is ASYNC with response-edge sync: decode bursts keep the
        emitted token columns as device arrays in flight (one [B, burst]
        block per burst, no per-step host readback), and the host
        materializes a block only at a response edge -- when some slot's
        request completes at the end of a burst.  Blocks every live slot
        has consumed are dropped, so in-flight device memory stays bounded
        by the longest active request.

        With `draft_len` set, the burst loop is the speculative variant
        (`_run_speculative`): host-synced per burst (the n-gram drafter
        needs emitted ids), one verify+commit device step per burst."""
        if self.draft_len:
            return self._run_speculative()
        results: Dict[int, np.ndarray] = {}
        sched, B = self._sched, self.batch
        if not sched.pending(_LM):
            return results
        plen = self.prefill_len
        if plen is None:
            plen = max(len(p) for p, _ in sched.peek(_LM))
        prefill_exec = (self._paged_prefill_exec() if self.paged
                        else self._prefill_exec())
        decode_exec = self._decode_exec()

        cache = self._run_cache(B)
        cur = jnp.zeros((B, 1), jnp.int32)
        tickets: List[Optional[int]] = [None] * B
        remaining = np.zeros(B, np.int64)
        start = np.zeros(B, np.int64)     # slot's first global step
        step = 0                          # global decode-step counter
        blocks: List[List] = []           # [start step, [B, w] device toks]
        block_np: Dict[int, np.ndarray] = {}   # id(block) -> host tokens

        def tokens_for(slot: int, lo: int, hi: int) -> np.ndarray:
            """Materialize steps [lo, hi) of one slot from the in-flight
            blocks -- the response edge's only host sync."""
            parts = []
            for s0, blk in blocks:
                w = blk.shape[1]
                if s0 + w <= lo or s0 >= hi:
                    continue
                arr = block_np.get(id(blk))
                if arr is None:
                    arr = block_np[id(blk)] = np.asarray(blk)
                parts.append(arr[slot, max(lo - s0, 0):min(hi - s0, w)])
            return (np.concatenate(parts).astype(np.int32) if parts
                    else np.zeros(0, np.int32))

        while True:
            free = [i for i in range(B) if remaining[i] == 0]
            if free and sched.pending(_LM):
                taken = self._admit(len(free), plen)
                if taken:
                    toks = np.zeros((B, plen), np.int32)
                    mask = np.zeros(B, bool)
                    matched = np.full(B, plen, np.int32)
                    for slot, (ticket, (prompt, mnt)) in zip(free, taken):
                        if len(prompt) > plen:
                            raise ValueError(
                                f"prompt of length {len(prompt)} exceeds the "
                                f"run's fixed prefill width {plen} (set "
                                f"prefill_len at construction)")
                        toks[slot, plen - len(prompt):] = prompt
                        mask[slot] = True
                        if tickets[slot] is not None:
                            self.serve_stats.slot_refills += 1
                        tickets[slot] = ticket
                        remaining[slot] = mnt
                        start[slot] = step
                        if self.paged:
                            matched[slot] = self._bind_blocks(
                                slot, plen, mnt,
                                row=(toks[slot] if self.prefix_sharing
                                     else None))
                    jmask = jnp.asarray(mask)
                    # batched prefill of the refill slots only; foreign rows
                    # compute garbage that the masked merge throws away
                    if self.paged:
                        # admission edge: push the host table (new rows AND
                        # rows cleared at response edges) before any writes
                        cache["tables"] = jnp.asarray(self._host_tables)
                        if self.prefix_sharing:
                            logits, cache = self._shared_prefill(
                                cache, toks, mask, matched)
                        else:
                            logits, cache = prefill_exec(
                                self.params, cache,
                                {"tokens": jnp.asarray(toks)}, jmask)
                            self.serve_stats.prefill_tokens_computed += (
                                len(taken) * plen)
                    else:
                        logits, fresh = prefill_exec(
                            self.params, self._empty_cache(),
                            {"tokens": jnp.asarray(toks)})
                        cache = self.jmerge(cache, fresh, jmask)
                        self.serve_stats.prefill_tokens_computed += (
                            len(taken) * plen)
                    first = jnp.argmax(logits[:, -1, :], axis=-1)
                    cur = jnp.where(jmask[:, None], first[:, None], cur
                                    ).astype(jnp.int32)
                    self.serve_stats.prefill_calls += 1
                    self.serve_stats.requests += len(taken)
                    sched.next_epoch()

            act = [i for i in range(B) if remaining[i] > 0]
            if not act:
                if sched.pending(_LM):
                    self._ensure_admissible(plen)
                    continue
                break
            burst = int(min(self.decode_burst,
                            min(remaining[i] for i in act)))
            cols = []
            for _ in range(burst):
                cols.append(cur)          # emitted token, still on device
                logits, cache = decode_exec(self.params, cache, cur)
                cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None
                                                            ].astype(jnp.int32)
                self.serve_stats.decode_steps += 1
                self.serve_stats.active_slot_steps += len(act)
            blocks.append([step, cols[0] if burst == 1
                           else jnp.concatenate(cols, axis=1)])
            step += burst
            finished = False
            for i in act:
                remaining[i] -= burst
                if remaining[i] == 0:     # response edge for this ticket
                    results[tickets[i]] = tokens_for(i, int(start[i]), step)
                    self.latency.completed(tickets[i])
                    if self.paged:
                        self._release_blocks(i)
                    finished = True
            if finished:
                # drop blocks every live slot is past (bounded in-flight)
                live = [int(start[i]) for i in range(B) if remaining[i] > 0]
                lo = min(live) if live else step
                keep = [b for b in blocks if b[0] + b[1].shape[1] > lo]
                kept_ids = {id(b[1]) for b in keep}
                for b in blocks:
                    if id(b[1]) not in kept_ids:
                        block_np.pop(id(b[1]), None)
                blocks = keep
        if self.prefix_sharing:
            self._pool_cache = cache   # warm prefix bits survive the run
        return results

    @staticmethod
    def _ngram_draft(hist: List[int], k: int, max_n: int = 3) -> List[int]:
        """Self-speculative n-gram proposal: k draft tokens continuing
        `hist` (prompt + emitted ids, most recent last).  Matches the
        longest suffix n-gram (n <= max_n) against earlier history and
        copies what followed its most recent occurrence; with no match it
        repeats the last token.  Pure host-side -- no second model, no
        device work; a wrong draft only costs its share of the burst."""
        seq = list(hist)
        for _ in range(k):
            nxt = None
            for n in range(min(max_n, len(seq) - 1), 0, -1):
                suf = seq[-n:]
                for j in range(len(seq) - n - 1, -1, -1):
                    if seq[j:j + n] == suf:
                        nxt = seq[j + n]
                        break
                if nxt is not None:
                    break
            seq.append(seq[-1] if nxt is None else nxt)
        return seq[-k:]

    def _run_speculative(self) -> Dict[int, np.ndarray]:
        """Speculative continuous batching: each burst teacher-forces the
        current token plus `draft_len` n-gram drafts through ONE [B, 1+k]
        verify step, commits the longest greedy-consistent prefix, and
        rolls the rest back for free (rejected drafts never touched the
        cache).  Emitted ids are token-for-token identical to the greedy
        one-token loop; each burst commits 1..1+k tokens.  Host-synced per
        burst: the drafter consumes emitted ids (that sync replaces run()'s
        async block machinery)."""
        results: Dict[int, np.ndarray] = {}
        sched, B, W = self._sched, self.batch, 1 + self.draft_len
        if not sched.pending(_LM):
            return results
        plen = self.prefill_len
        if plen is None:
            plen = max(len(p) for p, _ in sched.peek(_LM))
        prefill_exec = (self._paged_prefill_exec() if self.paged
                        else self._prefill_exec())
        spec_exec = self._spec_exec()

        cache = self._run_cache(B)
        cur = np.zeros(B, np.int32)
        tickets: List[Optional[int]] = [None] * B
        remaining = np.zeros(B, np.int64)
        hist: List[List[int]] = [[] for _ in range(B)]  # prompt + emitted
        out: List[List[int]] = [[] for _ in range(B)]

        while True:
            free = [i for i in range(B) if remaining[i] == 0]
            if free and sched.pending(_LM):
                taken = self._admit(len(free), plen)
                if taken:
                    toks = np.zeros((B, plen), np.int32)
                    mask = np.zeros(B, bool)
                    matched = np.full(B, plen, np.int32)
                    for slot, (ticket, (prompt, mnt)) in zip(free, taken):
                        if len(prompt) > plen:
                            raise ValueError(
                                f"prompt of length {len(prompt)} exceeds "
                                f"the run's fixed prefill width {plen} "
                                "(set prefill_len at construction)")
                        toks[slot, plen - len(prompt):] = prompt
                        mask[slot] = True
                        if tickets[slot] is not None:
                            self.serve_stats.slot_refills += 1
                        tickets[slot] = ticket
                        remaining[slot] = mnt
                        hist[slot] = [int(t) for t in prompt]
                        out[slot] = []
                        if self.paged:
                            matched[slot] = self._bind_blocks(
                                slot, plen, mnt,
                                row=(toks[slot] if self.prefix_sharing
                                     else None))
                    jmask = jnp.asarray(mask)
                    if self.paged:
                        cache["tables"] = jnp.asarray(self._host_tables)
                        if self.prefix_sharing:
                            logits, cache = self._shared_prefill(
                                cache, toks, mask, matched)
                        else:
                            logits, cache = prefill_exec(
                                self.params, cache,
                                {"tokens": jnp.asarray(toks)}, jmask)
                            self.serve_stats.prefill_tokens_computed += (
                                len(taken) * plen)
                    else:
                        logits, fresh = prefill_exec(
                            self.params, self._empty_cache(),
                            {"tokens": jnp.asarray(toks)})
                        cache = self.jmerge(cache, fresh, jmask)
                        self.serve_stats.prefill_tokens_computed += (
                            len(taken) * plen)
                    first = np.asarray(jnp.argmax(logits[:, -1, :], -1))
                    for slot in free[:len(taken)]:
                        cur[slot] = first[slot]
                    self.serve_stats.prefill_calls += 1
                    self.serve_stats.requests += len(taken)
                    sched.next_epoch()

            act = [i for i in range(B) if remaining[i] > 0]
            if not act:
                if sched.pending(_LM):
                    self._ensure_admissible(plen)
                    continue
                break

            tok = np.zeros((B, W), np.int32)
            cap = np.zeros(B, np.int32)
            for i in act:
                tok[i, 0] = cur[i]
                if W > 1:
                    tok[i, 1:] = self._ngram_draft(hist[i] + [int(cur[i])],
                                                   W - 1)
                cap[i] = min(int(remaining[i]), W)
            accept, nxt, cache = spec_exec(self.params, cache,
                                           jnp.asarray(tok),
                                           jnp.asarray(cap))
            accept, nxt = np.asarray(accept), np.asarray(nxt)
            self.serve_stats.decode_steps += 1
            self.serve_stats.spec_steps += 1
            self.serve_stats.spec_slot_steps += len(act)
            self.serve_stats.active_slot_steps += len(act)
            for i in act:
                a = int(accept[i])
                emitted = tok[i, :a].tolist()
                out[i].extend(emitted)
                hist[i].extend(emitted)
                cur[i] = nxt[i]
                remaining[i] -= a
                self.serve_stats.committed_tokens += a
                self.serve_stats.drafted_tokens += int(cap[i]) - 1
                self.serve_stats.accepted_drafts += a - 1
                if remaining[i] == 0:     # response edge
                    results[tickets[i]] = np.asarray(out[i], np.int32)
                    self.latency.completed(tickets[i])
                    if self.paged:
                        self._release_blocks(i)
        if self.prefix_sharing:
            self._pool_cache = cache   # warm prefix bits survive the run
        return results

    # -- generation ----------------------------------------------------------

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 16,
                 enc_embeds: Optional[np.ndarray] = None) -> List[np.ndarray]:
        """Greedy generation for a batch of equal-priority requests, in
        submission order -- submit() + run() over the continuous scheduler.
        Audio (encoder-decoder) archs serve on the legacy wave path."""
        if self.is_audio or enc_embeds is not None:
            return self._generate_waves(prompts, max_new_tokens, enc_embeds)
        tickets = [self.submit(p, max_new_tokens) for p in prompts]
        rejected = [t for t in tickets if isinstance(t, SubmitRejection)]
        if rejected:
            raise ValueError(f"{len(rejected)} of {len(prompts)} prompts "
                             f"rejected: {rejected[0].detail}")
        results = self.run()
        return [results[t] for t in tickets]

    def _generate_waves(self, prompts, max_new_tokens, enc_embeds):
        """Fixed waves of `batch` requests (the audio fallback path)."""
        out: List[np.ndarray] = []
        for start in range(0, len(prompts), self.batch):
            wave = list(prompts[start:start + self.batch])
            n = len(wave)
            plen = max(len(p) for p in wave)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, p in enumerate(wave):
                toks[i, plen - len(p):] = p      # left-pad into the batch
            cache = self._empty_cache()
            batch = {"tokens": jnp.asarray(toks)}
            if self.is_audio:
                ee = (enc_embeds if enc_embeds is not None else
                      np.zeros((self.batch, self.arch.encoder_seq,
                                self.arch.d_model), np.float32))
                batch["enc_embeds"] = jnp.asarray(ee[:self.batch])
            logits, cache = self.jprefill(self.params, cache, batch)
            seqs = [[] for _ in range(n)]
            cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            for step in range(max_new_tokens):
                for i in range(n):
                    seqs[i].append(int(cur[i, 0]))
                logits, cache = self.jdecode(self.params, cache, cur)
                cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            out.extend(np.asarray(s, np.int32) for s in seqs)
        return out

    # -- stats ---------------------------------------------------------------

    def _kv_memory(self) -> Dict[str, float]:
        """Measured KV-cache footprint: total bytes of global-layer KV
        state, and bytes a single request actually occupies (dense: the
        worst-case max_seq envelope every slot reserves; paged: mean blocks
        held per admitted request)."""
        itm = 1 if self.eng.kv_cache_dtype == "int8" else 2
        nkv, hd = self.arch.n_kv_heads, self.arch.head_dim
        per_pos = 2 * nkv * hd * itm      # k + v
        if self.eng.kv_cache_dtype == "int8":
            per_pos += 2 * nkv * 4        # k_scale + v_scale
        n_glb = sum(1 for i in range(self.arch.n_layers)
                    if self.arch.layer_kind(i) not in
                    ("local", "mamba", "recurrent"))
        if self.paged:
            block_bytes = self.page_size * per_pos * n_glb
            st = self.alloc.stats
            per_slot = (block_bytes * st.blocks_served / st.allocs
                        if st.allocs else float(block_bytes * self.kv_pages))
            return {"kv_bytes": float(block_bytes * self.alloc.num_blocks),
                    "kv_bytes_per_slot": per_slot,
                    "kv_block_bytes": float(block_bytes)}
        per_slot = float(self.max_seq * per_pos * n_glb)
        return {"kv_bytes": per_slot * self.batch,
                "kv_bytes_per_slot": per_slot}

    def stats(self) -> Dict[str, object]:
        out = {"arch": self.arch.name,
               "compiled_prefill": self.compiled,
               "compiled_decode": self.compiled_decode,
               "schedule_policy": self.schedule_policy,
               "kv_layout": self.kv_layout,
               "draft_len": self.draft_len,
               # the eager-fallback gate, made loud: WHY an arch fell back
               "lowering_blockers": self.lowering_blockers()}
        out.update(self.cache_stats())
        s = self.serve_stats
        out.update({
            "requests": s.requests,
            "prefill_calls": s.prefill_calls,
            "decode_steps": s.decode_steps,
            "slot_refills": s.slot_refills,
            "slot_refill_rate": s.refill_rate,
            "slot_occupancy": s.slot_occupancy,
            "rejected_requests": s.rejected_requests,
            "prefill_tokens_computed": s.prefill_tokens_computed,
            "latency_ms": self.latency.percentiles(),
        })
        out.update(self._kv_memory())
        if self.paged:
            out["page_size"] = self.page_size
            out["kv_blocks"] = self.alloc.describe()
        if self.prefix_sharing or self.prefix_sharing_blockers:
            ps = {"enabled": self.prefix_sharing,
                  "blockers": list(self.prefix_sharing_blockers)}
            if self.prefix_index is not None:
                ps.update({
                    "hits": s.prefix_hits,
                    "misses": s.prefix_misses,
                    "shared_blocks": s.prefix_shared_blocks,
                    "evictions": self.prefix_index.evictions,
                    "index_nodes": len(self.prefix_index),
                    "held_only": self.prefix_index.held_only(),
                })
            out["prefix_sharing"] = ps
        if self.draft_len:
            out.update({
                "spec_steps": s.spec_steps,
                "accepted_draft_rate": s.accepted_draft_rate,
                "tokens_per_burst": s.tokens_per_burst,
            })
        if self.mexec is not None:
            out["mesh"] = self.mexec.describe()
            if self.tp_placement is not None:
                out["tp_placement"] = self.tp_placement
        for tag, key in (("prefill", self._prefill_key()),
                         ("decode", self._decode_key())):
            program = self.cache.peek(key)
            if program is not None and program.schedule is not None:
                out[f"{tag}_levels"] = program.schedule.n_levels
                occ = compiler.engine_occupancy(program.graph,
                                                program.schedule)
                out[f"{tag}_occupancy"] = occ["occupancy"]
        return out


def throughput_probe(engine: ServeEngine, steps: int = 8) -> dict:
    """Tokens/s of the decode loop (CPU wall-clock; relative numbers only)."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, engine.arch.vocab_size, size=8)
               for _ in range(engine.batch)]
    engine.generate(prompts, max_new_tokens=1)     # compile outside the clock
    t0 = time.perf_counter()
    engine.generate(prompts, max_new_tokens=steps)
    dt = time.perf_counter() - t0
    return {"tokens_per_s": engine.batch * steps / dt, "wall_s": dt}
