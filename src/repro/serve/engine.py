"""Serving engine: batched prefill + greedy decode with a request scheduler.

This is the small-scale executable counterpart of launch/build.build_serve
(which produces the production-mesh programs).  ServeEngine runs real tokens
on the local device(s): quantize -> prefill -> decode loop, with batching of
incoming requests into fixed slots (a static-batch continuous-batching
scheduler: finished slots are refilled between decode bursts)."""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine as eng_lib
from repro.core.config import ArchConfig, EngineConfig
from repro.models import params as prm
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models.params import is_spec


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # [L] int32
    max_new_tokens: int = 16
    out_tokens: Optional[list] = None


class ServeEngine:
    def __init__(self, arch: ArchConfig, params, eng: EngineConfig,
                 batch_size: int = 4, max_seq: int = 256):
        self.arch, self.eng = arch, eng
        self.batch, self.max_seq = batch_size, max_seq
        self.params = eng_lib.quantize_params(params, eng)
        self.is_audio = arch.family == "audio"
        mod = W if self.is_audio else T
        self.mod = mod

        def _prefill(params, cache, batch):
            return mod.prefill(params, cache, batch, arch, eng)

        def _decode(params, cache, tokens):
            return mod.decode(params, cache, tokens, arch, eng)

        self.jprefill = jax.jit(_prefill, donate_argnums=(1,))
        self.jdecode = jax.jit(_decode, donate_argnums=(1,))

    def _empty_cache(self):
        if self.is_audio:
            cs = W.whisper_cache_schema(self.arch, self.batch, self.max_seq,
                                        self.eng)
        else:
            cs = T.cache_schema(self.arch, self.batch, self.max_seq, self.eng)
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), cs, is_leaf=is_spec)

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 16,
                 enc_embeds: Optional[np.ndarray] = None) -> List[np.ndarray]:
        """Greedy generation for a batch of equal-priority requests.
        Requests beyond the batch size are processed in waves."""
        out: List[np.ndarray] = []
        for start in range(0, len(prompts), self.batch):
            wave = list(prompts[start:start + self.batch])
            n = len(wave)
            plen = max(len(p) for p in wave)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, p in enumerate(wave):
                toks[i, plen - len(p):] = p      # left-pad into the batch
            cache = self._empty_cache()
            batch = {"tokens": jnp.asarray(toks)}
            if self.is_audio:
                ee = (enc_embeds if enc_embeds is not None else
                      np.zeros((self.batch, self.arch.encoder_seq,
                                self.arch.d_model), np.float32))
                batch["enc_embeds"] = jnp.asarray(ee[:self.batch])
            logits, cache = self.jprefill(self.params, cache, batch)
            seqs = [[] for _ in range(n)]
            cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            for step in range(max_new_tokens):
                for i in range(n):
                    seqs[i].append(int(cur[i, 0]))
                logits, cache = self.jdecode(self.params, cache, cur)
                cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            out.extend(np.asarray(s, np.int32) for s in seqs)
        return out


def throughput_probe(engine: ServeEngine, steps: int = 8) -> dict:
    """Tokens/s of the decode loop (CPU wall-clock; relative numbers only)."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, engine.arch.vocab_size, size=8)
               for _ in range(engine.batch)]
    t0 = time.perf_counter()
    engine.generate(prompts, max_new_tokens=steps)
    dt = time.perf_counter() - t0
    return {"tokens_per_s": engine.batch * steps / dt, "wall_s": dt}
