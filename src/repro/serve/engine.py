"""Serving engine: batched prefill + greedy decode with a request scheduler.

This is the small-scale executable counterpart of launch/build.build_serve
(which produces the production-mesh programs).  ServeEngine runs real tokens
on the local device(s): quantize -> prefill -> decode loop, with batching of
incoming requests into fixed slots (a static-batch continuous-batching
scheduler: finished slots are refilled between decode bursts).

Prefill rides the unified serve path (serve/base.py): the transformer
lowers through the model-agnostic engine IR (compiler.lower_transformer)
into a program cached in the keyed ProgramCache -- the same
compile -> cache -> schedule pipeline CNNServeEngine uses -- keyed by
(ArchConfig, EngineConfig, calibration-id).  With calibration token batches
and a w8a8 engine the program is static-int8: every projection GEMM
consumes activations pre-quantized at compile-time scales instead of
re-quantizing per token.  The compiled program also fills the decode KV
cache (each AttnOp deposits its roped-k/v pair), so one program replaces
`T.prefill`.  Decode, SSM/MoE mixers, and the audio encoder-decoder stay on
the eager path.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compiler
from repro.compiler import executor as ex
from repro.core import engine as eng_lib
from repro.core.config import ArchConfig, EngineConfig
from repro.models import params as prm
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models.params import is_spec
from repro.serve.base import ProgramServeBase, calibration_digest
from repro.serve.program_cache import ProgramCache


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # [L] int32
    max_new_tokens: int = 16
    out_tokens: Optional[list] = None


class ServeEngine(ProgramServeBase):
    def __init__(self, arch: ArchConfig, params, eng: EngineConfig,
                 batch_size: int = 4, max_seq: int = 256,
                 calib_batches: Optional[Sequence] = None,
                 calibrator: str = "absmax",
                 cache: Optional[ProgramCache] = None,
                 cache_capacity: int = 4, scheduled: bool = True,
                 schedule_policy: str = "asap",
                 compile_prefill: bool = True):
        super().__init__(eng, cache_capacity=cache_capacity,
                         scheduled=scheduled, cache=cache,
                         schedule_policy=schedule_policy)
        self.arch = arch
        self.batch, self.max_seq = batch_size, max_seq
        self._float_params = params
        self.params = eng_lib.quantize_params(params, eng)
        self.is_audio = arch.family == "audio"
        mod = W if self.is_audio else T
        self.mod = mod
        # Prefill compiles through the engine IR when the arch lowers;
        # SSM / MoE / audio archs fall back to the eager path.
        self.compiled = (compile_prefill and not self.is_audio
                         and compiler.can_lower(arch))
        # calibration only feeds the compiled static program; skip the
        # (whole-param-tree) digest when prefill stays eager
        batches = (list(calib_batches)
                   if calib_batches is not None and eng.quant == "w8a8"
                   and self.compiled else None)
        self.calib_batches = batches
        self.calib_id = (calibration_digest(batches, params, calibrator)
                         if batches is not None else None)
        self.calibrator = calibrator

        def _prefill(params, cache, batch):
            return mod.prefill(params, cache, batch, arch, eng)

        def _decode(params, cache, tokens):
            return mod.decode(params, cache, tokens, arch, eng)

        self.jprefill = jax.jit(_prefill, donate_argnums=(1,))
        self.jdecode = jax.jit(_decode, donate_argnums=(1,))

    # -- compiled prefill (the unified serve path) ---------------------------

    def _prefill_key(self):
        return self._program_key(self.arch, self.calib_id, tag="prefill")

    def _compile_prefill(self) -> ex.Program:
        if self.calib_batches is None:
            return compiler.compile_lm(self.arch, scheduled=self.scheduled,
                                       policy=self.schedule_policy,
                                       prefill=True)
        return compiler.compile_lm_calibrated(
            self.arch, self._float_params, self.calib_batches,
            scheduled=self.scheduled, policy=self.schedule_policy,
            method=self.calibrator, prefill=True)

    def prefill_program(self) -> ex.Program:
        """The compiled prefill program: ProgramCache hit, or compile."""
        return self._cached_program(self._prefill_key(),
                                    self._compile_prefill)

    def _run_program_prefill(self, program: ex.Program, params, cache,
                             batch):
        """Execute the prefill program and write the collected per-layer
        (k, v) pairs into the decode cache -- the compiled counterpart of
        `T.prefill` (bit-identical cache layout)."""
        tokens = batch["tokens"]
        kvs: Dict[int, tuple] = {}
        logits = ex.execute(program, params, tokens, self.eng, collect=kvs)
        new_layers = []
        for i in range(self.arch.n_layers):
            entry = cache["layers"][i]
            k, v = kvs[i]
            if self.arch.layer_kind(i) == "local":
                w = entry["k"].shape[1]
                entry = T._kv_store(entry, k[:, -w:], v[:, -w:], 0, self.eng)
            else:
                entry = T._kv_store(entry, k, v, 0, self.eng)
            new_layers.append(entry)
        return logits, {"layers": new_layers,
                        "pos": jnp.asarray(tokens.shape[1], jnp.int32)}

    def _prefill_exec(self):
        """The jitted prefill executable: the eager path, or the cached
        program's (traced once per cached program; stats accrue per call)."""
        if not self.compiled:
            return self.jprefill
        program = self.prefill_program()
        return self._jitted_for(
            self._prefill_key(), program,
            lambda prog: jax.jit(
                functools.partial(self._run_program_prefill, prog),
                donate_argnums=(1,)))

    # -- generation ----------------------------------------------------------

    def _empty_cache(self):
        if self.is_audio:
            cs = W.whisper_cache_schema(self.arch, self.batch, self.max_seq,
                                        self.eng)
        else:
            cs = T.cache_schema(self.arch, self.batch, self.max_seq, self.eng)
        return jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), cs, is_leaf=is_spec)

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 16,
                 enc_embeds: Optional[np.ndarray] = None) -> List[np.ndarray]:
        """Greedy generation for a batch of equal-priority requests.
        Requests beyond the batch size are processed in waves."""
        out: List[np.ndarray] = []
        for start in range(0, len(prompts), self.batch):
            wave = list(prompts[start:start + self.batch])
            n = len(wave)
            plen = max(len(p) for p in wave)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, p in enumerate(wave):
                toks[i, plen - len(p):] = p      # left-pad into the batch
            cache = self._empty_cache()
            batch = {"tokens": jnp.asarray(toks)}
            if self.is_audio:
                ee = (enc_embeds if enc_embeds is not None else
                      np.zeros((self.batch, self.arch.encoder_seq,
                                self.arch.d_model), np.float32))
                batch["enc_embeds"] = jnp.asarray(ee[:self.batch])
            logits, cache = self._prefill_exec()(self.params, cache, batch)
            seqs = [[] for _ in range(n)]
            cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            for step in range(max_new_tokens):
                for i in range(n):
                    seqs[i].append(int(cur[i, 0]))
                logits, cache = self.jdecode(self.params, cache, cur)
                cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            out.extend(np.asarray(s, np.int32) for s in seqs)
        return out

    # -- stats ---------------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        out = {"arch": self.arch.name, "compiled_prefill": self.compiled}
        out.update(self.cache_stats())
        if self.compiled:
            program = self.cache.peek(self._prefill_key())
            if program is not None and program.schedule is not None:
                out["prefill_levels"] = program.schedule.n_levels
                occ = compiler.engine_occupancy(program.graph,
                                                program.schedule)
                out["prefill_occupancy"] = occ["occupancy"]
        return out


def throughput_probe(engine: ServeEngine, steps: int = 8) -> dict:
    """Tokens/s of the decode loop (CPU wall-clock; relative numbers only)."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, engine.arch.vocab_size, size=8)
               for _ in range(engine.batch)]
    t0 = time.perf_counter()
    engine.generate(prompts, max_new_tokens=steps)
    dt = time.perf_counter() - t0
    return {"tokens_per_s": engine.batch * steps / dt, "wall_s": dt}
