"""LM serving engine: compiled prefill + decode programs behind a
continuous-batching slot scheduler.

This is the small-scale executable counterpart of launch/build.build_serve
(which produces the production-mesh programs).  ServeEngine runs real tokens
on the local device(s) through the unified serve path (serve/base.py):

  * prefill AND decode both lower through the model-agnostic engine IR
    (compiler.lower_transformer) into programs cached in the keyed
    ProgramCache -- the same compile -> cache -> schedule pipeline
    CNNServeEngine uses -- keyed by (ArchConfig, EngineConfig,
    calibration-id) with distinct prefill/decode variants.  With
    calibration token batches and a w8a8 engine BOTH programs are
    static-int8 from ONE calibration run (compiler.calibrate_lm): every
    projection GEMM -- including every decode-step GEMM, the steady-state
    serving path -- consumes activations pre-quantized at compile-time
    scales instead of re-quantizing per token.
  * the compiled prefill program fills the decode KV cache (each AttnOp
    deposits its roped-k/v pair), and the compiled decode program IS the
    cache recurrence (AttnOp `update` mode): the decode burst executes it
    jit-once with the cache donated, exactly like the eager path it
    replaces.
  * requests queue in the shared SlotScheduler (serve/base.py): `submit()`
    enqueues (prompt, max_new_tokens); `run()` serves the whole queue with
    B fixed decode slots, refilling finished slots from the queue between
    decode bursts (continuous batching).  Prompts left-pad to one fixed
    prefill width, so a request's tokens depend only on its own padded
    slot row: with `prefill_len` pinned at construction, arrival order and
    batch composition cannot change its output (the order-invariance
    property test pins that; see run() on the unset-width default).

With `mesh=` the engine serves tensor-parallel (serve/mesh_exec.py):
projection weights shard over the mesh's "model" axis at whole-head
granularity, the KV cache replicates, and every decode-burst GEMM runs
sharded -- bit-identical to single-device execution (the sharded-parity
property test pins it).  Decode dispatch is async: bursts keep emitted
token columns on device and the host syncs only at response edges (a
request completing), never per step.

SSM / MoE mixers and the audio encoder-decoder stay eager: `stats()`
reports the exact `lowering_blockers` instead of silently falling back.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import compiler
from repro.compiler import executor as ex
from repro.core import engine as eng_lib
from repro.core.config import ArchConfig, EngineConfig
from repro.models import params as prm
from repro.models import transformer as T
from repro.models import whisper as W
from repro.models.params import is_spec
from repro.serve.base import (ProgramServeBase, SlotScheduler,
                              calibration_digest)
from repro.serve.program_cache import ProgramCache

_LM = "lm"                            # the scheduler's single slot group


@dataclasses.dataclass
class Request:
    prompt: np.ndarray              # [L] int32
    max_new_tokens: int = 16
    out_tokens: Optional[list] = None


@dataclasses.dataclass
class LMServeStats:
    """Continuous-batching counters across run() calls."""
    requests: int = 0
    prefill_calls: int = 0            # batched prefill executions
    decode_steps: int = 0             # decode program/burst steps
    active_slot_steps: int = 0        # slot-steps that served a request
    slot_refills: int = 0             # slots reused after a finished request
    batch: int = 0

    @property
    def slot_occupancy(self) -> float:
        total = self.decode_steps * max(self.batch, 1)
        return self.active_slot_steps / total if total else 0.0

    @property
    def refill_rate(self) -> float:
        """Fraction of requests admitted by refilling a finished slot
        mid-run rather than by the initial batch fill."""
        return self.slot_refills / self.requests if self.requests else 0.0


class ServeEngine(ProgramServeBase):
    def __init__(self, arch: ArchConfig, params, eng: EngineConfig,
                 batch_size: int = 4, max_seq: int = 256,
                 calib_batches: Optional[Sequence] = None,
                 calibrator: str = "absmax",
                 granularity: str = "per_tensor",
                 cache: Optional[ProgramCache] = None,
                 cache_capacity: int = 4, scheduled: bool = True,
                 schedule_policy: str = "asap",
                 compile_prefill: bool = True,
                 compile_decode: bool = True,
                 decode_burst: int = 4,
                 prefill_len: Optional[int] = None,
                 mesh=None):
        super().__init__(eng, cache_capacity=cache_capacity,
                         scheduled=scheduled, cache=cache,
                         schedule_policy=schedule_policy, mesh=mesh)
        self.arch = arch
        self.batch, self.max_seq = batch_size, max_seq
        self.decode_burst = max(1, decode_burst)
        self.prefill_len = prefill_len
        self._float_params = params
        self.params = eng_lib.quantize_params(params, eng)
        self.is_audio = arch.family == "audio"
        # mesh= places the param tree tensor-parallel over the "model"
        # axis (whole-head granularity; see serve/mesh_exec.py) -- decode
        # bursts then run their projection GEMMs sharded, bit-identical
        # to single-device
        self.tp_placement = None
        if self.mexec is not None:
            if self.is_audio:
                self.params = self.mexec.replicate(self.params)
            else:
                self.params, self.tp_placement = \
                    self.mexec.place_lm_params(arch, self.params)
        mod = W if self.is_audio else T
        self.mod = mod
        # Prefill/decode compile through the engine IR when the arch
        # lowers; SSM / MoE / audio archs fall back to the eager path and
        # stats() carries the blockers.
        lowerable = not self.is_audio and compiler.can_lower(arch)
        self.compiled = compile_prefill and lowerable
        self.compiled_decode = compile_decode and lowerable
        # calibration only feeds the compiled static programs; skip the
        # (whole-param-tree) digest when both paths stay eager.  w4a8
        # shares w8a8's activation calibration (same float graph, same
        # scales) but the digest carries weight_mode so w4 and w8 programs
        # key distinct ProgramCache lines.
        batches = (list(calib_batches)
                   if calib_batches is not None
                   and eng.quant in ("w8a8", "w4a8")
                   and (self.compiled or self.compiled_decode) else None)
        self.calib_batches = batches
        self.calib_id = (calibration_digest(
                             batches, params, calibrator, granularity,
                             weight_mode=eng_lib.weight_mode(eng))
                         if batches is not None else None)
        self.calibrator = calibrator
        self.granularity = granularity
        self._scales = None           # one calibration run, both programs
        self._sched = SlotScheduler(batch_size)
        self.serve_stats = LMServeStats(batch=batch_size)

        def _prefill(params, cache, batch):
            return mod.prefill(params, cache, batch, arch, eng)

        def _decode(params, cache, tokens):
            return mod.decode(params, cache, tokens, arch, eng)

        self.jprefill = jax.jit(_prefill, donate_argnums=(1,))
        self.jdecode = jax.jit(_decode, donate_argnums=(1,))

        def _merge(old, new, mask):
            """Scatter refilled slots' prefill state into the live cache:
            per-slot row select on every [B, ...] leaf, per-slot pos."""
            def sel(o, n):
                m = mask.reshape((mask.shape[0],) + (1,) * (o.ndim - 1))
                return jnp.where(m, n.astype(o.dtype), o)
            layers = jax.tree_util.tree_map(sel, old["layers"],
                                            new["layers"])
            pos = jnp.where(mask, jnp.asarray(new["pos"], jnp.int32),
                            jnp.asarray(old["pos"], jnp.int32))
            return {"layers": layers, "pos": pos}

        self.jmerge = jax.jit(_merge, donate_argnums=(0, 1))

    # -- compiled programs (the unified serve path) --------------------------

    def lowering_blockers(self) -> List[str]:
        """Why this arch's programs fell back to eager ([] = compiled)."""
        if self.is_audio:
            return ["encoder-decoder (audio)"]
        return compiler.lowering_blockers(self.arch)

    def _lm_scales(self):
        """The shared calibration run: one execution of the calibration
        batches quantizes prefill AND decode (graph node ids line up)."""
        if self._scales is None:
            self._scales = compiler.calibrate_lm(
                self.arch, self._float_params, self.calib_batches,
                method=self.calibrator, granularity=self.granularity)
        return self._scales

    def _prefill_key(self):
        return self._program_key(self.arch, self.calib_id, tag="prefill")

    def _decode_key(self):
        return self._program_key(self.arch, self.calib_id, tag="decode")

    def _compile_mode(self, mode: str) -> ex.Program:
        if self.calib_batches is None:
            return compiler.compile_lm(self.arch, scheduled=self.scheduled,
                                       policy=self.schedule_policy,
                                       mode=mode)
        return compiler.compile_lm(self.arch, scales=self._lm_scales(),
                                   scheduled=self.scheduled,
                                   policy=self.schedule_policy, mode=mode,
                                   granularity=self.granularity)

    def prefill_program(self) -> ex.Program:
        """The compiled prefill program: ProgramCache hit, or compile."""
        return self._cached_program(self._prefill_key(),
                                    lambda: self._compile_mode("prefill"))

    def decode_program(self) -> ex.Program:
        """The compiled DecodeStep program: ProgramCache hit, or compile."""
        return self._cached_program(self._decode_key(),
                                    lambda: self._compile_mode("decode"))

    def _run_program_prefill(self, program: ex.Program, params, cache,
                             batch):
        """Execute the prefill program and write the collected per-layer
        (k, v) pairs into the decode cache -- the compiled counterpart of
        `T.prefill` (bit-identical cache layout)."""
        tokens = batch["tokens"]
        kvs: Dict[int, tuple] = {}
        logits = ex.execute(program, params, tokens, self.eng, collect=kvs)
        new_layers = []
        for i in range(self.arch.n_layers):
            entry = cache["layers"][i]
            k, v = kvs[i]
            if self.arch.layer_kind(i) == "local":
                w = entry["k"].shape[1]
                entry = T._kv_store(entry, k[:, -w:], v[:, -w:], 0, self.eng)
            else:
                entry = T._kv_store(entry, k, v, 0, self.eng)
            new_layers.append(entry)
        return logits, {"layers": new_layers,
                        "pos": jnp.asarray(tokens.shape[1], jnp.int32)}

    def _prefill_exec(self):
        """The jitted prefill executable: the eager path, or the cached
        program's (traced once per cached program; stats accrue per call)."""
        if not self.compiled:
            return self.jprefill
        program = self.prefill_program()
        return self._jitted_for(
            self._prefill_key(), program,
            lambda prog: jax.jit(
                functools.partial(self._run_program_prefill, prog),
                donate_argnums=(1,)))

    def _decode_exec(self):
        """The jitted decode-step executable: the compiled DecodeStep
        program from the ProgramCache (jit-once, cache donated), or the
        eager `T.decode` for fallback archs."""
        if not self.compiled_decode:
            return self.jdecode
        program = self.decode_program()
        return self._jitted_for(
            self._decode_key(), program,
            lambda prog: jax.jit(
                lambda params, cache, tokens: ex.execute_decode(
                    prog, params, cache, tokens, self.eng),
                donate_argnums=(1,)))

    # -- request queue / continuous batching ---------------------------------

    def _empty_cache(self):
        if self.is_audio:
            cs = W.whisper_cache_schema(self.arch, self.batch, self.max_seq,
                                        self.eng)
        else:
            cs = T.cache_schema(self.arch, self.batch, self.max_seq, self.eng)
        cache = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), cs, is_leaf=is_spec)
        if self.mexec is not None:
            cache = self.mexec.replicate(cache)   # KV cache stays replicated
        return cache

    def submit(self, prompt, max_new_tokens: int = 16) -> int:
        """Queue one prompt; returns its ticket (the key of its decoded
        token ids in run()'s results)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if max_new_tokens < 1:
            raise ValueError(
                f"max_new_tokens must be >= 1, got {max_new_tokens} (a "
                "0-token request would never own its slot and be dropped)")
        if len(prompt) + max_new_tokens > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens ({max_new_tokens})"
                f" exceeds max_seq={self.max_seq}")
        ticket = self._sched.submit(_LM, (prompt, int(max_new_tokens)))
        self.latency.submitted(ticket)
        return ticket

    def pending(self) -> int:
        return self._sched.pending(_LM)

    def run(self) -> Dict[int, np.ndarray]:
        """Serve the queue to completion with continuous batching: prefill
        fills free slots, decode bursts advance every slot one token per
        step, and finished slots refill from the queue between bursts.
        Returns {ticket: greedy token ids}.

        Every prompt left-pads to ONE prefill width (`prefill_len`, or the
        longest queued prompt when unset); pad tokens are ordinary context
        (no pad masking, like the legacy wave path), so a request's output
        is a function of its padded row alone.  With `prefill_len` set the
        row -- and therefore the output -- is independent of arrival order
        and batch composition (the order-invariance property test); with
        it unset, prompts shorter than the queue's max see a
        queue-dependent pad width, exactly as the per-wave padding before
        them did.

        Dispatch is ASYNC with response-edge sync: decode bursts keep the
        emitted token columns as device arrays in flight (one [B, burst]
        block per burst, no per-step host readback), and the host
        materializes a block only at a response edge -- when some slot's
        request completes at the end of a burst.  Blocks every live slot
        has consumed are dropped, so in-flight device memory stays bounded
        by the longest active request."""
        results: Dict[int, np.ndarray] = {}
        sched, B = self._sched, self.batch
        if not sched.pending(_LM):
            return results
        plen = self.prefill_len
        if plen is None:
            plen = max(len(p) for p, _ in sched.peek(_LM))
        prefill_exec = self._prefill_exec()
        decode_exec = self._decode_exec()

        cache = self._empty_cache()
        cache["pos"] = jnp.zeros((B,), jnp.int32)   # per-slot positions
        cur = jnp.zeros((B, 1), jnp.int32)
        tickets: List[Optional[int]] = [None] * B
        remaining = np.zeros(B, np.int64)
        start = np.zeros(B, np.int64)     # slot's first global step
        step = 0                          # global decode-step counter
        blocks: List[List] = []           # [start step, [B, w] device toks]
        block_np: Dict[int, np.ndarray] = {}   # id(block) -> host tokens

        def tokens_for(slot: int, lo: int, hi: int) -> np.ndarray:
            """Materialize steps [lo, hi) of one slot from the in-flight
            blocks -- the response edge's only host sync."""
            parts = []
            for s0, blk in blocks:
                w = blk.shape[1]
                if s0 + w <= lo or s0 >= hi:
                    continue
                arr = block_np.get(id(blk))
                if arr is None:
                    arr = block_np[id(blk)] = np.asarray(blk)
                parts.append(arr[slot, max(lo - s0, 0):min(hi - s0, w)])
            return (np.concatenate(parts).astype(np.int32) if parts
                    else np.zeros(0, np.int32))

        while True:
            free = [i for i in range(B) if remaining[i] == 0]
            if free and sched.pending(_LM):
                taken = sched.take(_LM, limit=len(free))
                toks = np.zeros((B, plen), np.int32)
                mask = np.zeros(B, bool)
                for slot, (ticket, (prompt, mnt)) in zip(free, taken):
                    if len(prompt) > plen:
                        raise ValueError(
                            f"prompt of length {len(prompt)} exceeds the "
                            f"run's fixed prefill width {plen} (set "
                            f"prefill_len at construction)")
                    toks[slot, plen - len(prompt):] = prompt
                    mask[slot] = True
                    if tickets[slot] is not None:
                        self.serve_stats.slot_refills += 1
                    tickets[slot] = ticket
                    remaining[slot] = mnt
                    start[slot] = step
                # batched prefill of the refill slots only; foreign rows
                # compute garbage that the masked merge throws away
                logits, fresh = prefill_exec(self.params, self._empty_cache(),
                                             {"tokens": jnp.asarray(toks)})
                jmask = jnp.asarray(mask)
                cache = self.jmerge(cache, fresh, jmask)
                first = jnp.argmax(logits[:, -1, :], axis=-1)
                cur = jnp.where(jmask[:, None], first[:, None], cur
                                ).astype(jnp.int32)
                self.serve_stats.prefill_calls += 1
                self.serve_stats.requests += len(taken)
                sched.next_epoch()

            act = [i for i in range(B) if remaining[i] > 0]
            if not act:
                if sched.pending(_LM):
                    continue
                break
            burst = int(min(self.decode_burst,
                            min(remaining[i] for i in act)))
            cols = []
            for _ in range(burst):
                cols.append(cur)          # emitted token, still on device
                logits, cache = decode_exec(self.params, cache, cur)
                cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None
                                                            ].astype(jnp.int32)
                self.serve_stats.decode_steps += 1
                self.serve_stats.active_slot_steps += len(act)
            blocks.append([step, cols[0] if burst == 1
                           else jnp.concatenate(cols, axis=1)])
            step += burst
            finished = False
            for i in act:
                remaining[i] -= burst
                if remaining[i] == 0:     # response edge for this ticket
                    results[tickets[i]] = tokens_for(i, int(start[i]), step)
                    self.latency.completed(tickets[i])
                    finished = True
            if finished:
                # drop blocks every live slot is past (bounded in-flight)
                live = [int(start[i]) for i in range(B) if remaining[i] > 0]
                lo = min(live) if live else step
                keep = [b for b in blocks if b[0] + b[1].shape[1] > lo]
                kept_ids = {id(b[1]) for b in keep}
                for b in blocks:
                    if id(b[1]) not in kept_ids:
                        block_np.pop(id(b[1]), None)
                blocks = keep
        return results

    # -- generation ----------------------------------------------------------

    def generate(self, prompts: Sequence[np.ndarray], max_new_tokens: int = 16,
                 enc_embeds: Optional[np.ndarray] = None) -> List[np.ndarray]:
        """Greedy generation for a batch of equal-priority requests, in
        submission order -- submit() + run() over the continuous scheduler.
        Audio (encoder-decoder) archs serve on the legacy wave path."""
        if self.is_audio or enc_embeds is not None:
            return self._generate_waves(prompts, max_new_tokens, enc_embeds)
        tickets = [self.submit(p, max_new_tokens) for p in prompts]
        results = self.run()
        return [results[t] for t in tickets]

    def _generate_waves(self, prompts, max_new_tokens, enc_embeds):
        """Fixed waves of `batch` requests (the audio fallback path)."""
        out: List[np.ndarray] = []
        for start in range(0, len(prompts), self.batch):
            wave = list(prompts[start:start + self.batch])
            n = len(wave)
            plen = max(len(p) for p in wave)
            toks = np.zeros((self.batch, plen), np.int32)
            for i, p in enumerate(wave):
                toks[i, plen - len(p):] = p      # left-pad into the batch
            cache = self._empty_cache()
            batch = {"tokens": jnp.asarray(toks)}
            if self.is_audio:
                ee = (enc_embeds if enc_embeds is not None else
                      np.zeros((self.batch, self.arch.encoder_seq,
                                self.arch.d_model), np.float32))
                batch["enc_embeds"] = jnp.asarray(ee[:self.batch])
            logits, cache = self.jprefill(self.params, cache, batch)
            seqs = [[] for _ in range(n)]
            cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            for step in range(max_new_tokens):
                for i in range(n):
                    seqs[i].append(int(cur[i, 0]))
                logits, cache = self.jdecode(self.params, cache, cur)
                cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
            out.extend(np.asarray(s, np.int32) for s in seqs)
        return out

    # -- stats ---------------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        out = {"arch": self.arch.name,
               "compiled_prefill": self.compiled,
               "compiled_decode": self.compiled_decode,
               "schedule_policy": self.schedule_policy,
               # the eager-fallback gate, made loud: WHY an arch fell back
               "lowering_blockers": self.lowering_blockers()}
        out.update(self.cache_stats())
        s = self.serve_stats
        out.update({
            "requests": s.requests,
            "prefill_calls": s.prefill_calls,
            "decode_steps": s.decode_steps,
            "slot_refills": s.slot_refills,
            "slot_refill_rate": s.refill_rate,
            "slot_occupancy": s.slot_occupancy,
            "latency_ms": self.latency.percentiles(),
        })
        if self.mexec is not None:
            out["mesh"] = self.mexec.describe()
            if self.tp_placement is not None:
                out["tp_placement"] = self.tp_placement
        for tag, key in (("prefill", self._prefill_key()),
                         ("decode", self._decode_key())):
            program = self.cache.peek(key)
            if program is not None and program.schedule is not None:
                out[f"{tag}_levels"] = program.schedule.n_levels
                occ = compiler.engine_occupancy(program.graph,
                                                program.schedule)
                out[f"{tag}_occupancy"] = occ["occupancy"]
        return out


def throughput_probe(engine: ServeEngine, steps: int = 8) -> dict:
    """Tokens/s of the decode loop (CPU wall-clock; relative numbers only)."""
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, engine.arch.vocab_size, size=8)
               for _ in range(engine.batch)]
    engine.generate(prompts, max_new_tokens=1)     # compile outside the clock
    t0 = time.perf_counter()
    engine.generate(prompts, max_new_tokens=steps)
    dt = time.perf_counter() - t0
    return {"tokens_per_s": engine.batch * steps / dt, "wall_s": dt}
