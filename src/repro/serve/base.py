"""Shared program-serving base: compile -> ProgramCache -> jit -> schedule.

Both serving engines ride this pipeline (the tentpole of the unified serve
path): `CNNServeEngine` serves registered CNN fleets as wave-batched
programs, and the LM `ServeEngine` serves transformer prefill from the same
kind of keyed cache.  The base owns what they share:

  * the keyed LRU ProgramCache (own or injected/shared across engines),
    keyed by (model config, EngineConfig, calibration-id, variant);
  * the schedule variant (ASAP / ALAP leveling, or sequential);
  * the per-program jitted-executable store, pruned against the cache so a
    shared cache's evictions drop stale traces here too;
  * cache statistics for the serving benchmarks.
"""
from __future__ import annotations

import hashlib
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.compiler.executor import Program, schedule_variant
from repro.core.config import EngineConfig
from repro.core.program_cache import ProgramCache, ProgramKey


def calibration_digest(batches: Sequence, params=None,
                       method: str = "absmax") -> str:
    """Stable id of the calibration inputs.  The recorded scales depend on
    the batches AND the float params (calibrate() runs the model) AND the
    calibrator method, so all three are digested: re-registering a model
    with new weights, new batches, or a different calibrator (absmax vs
    percentile) must miss the cache, not reuse stale activation scales."""
    h = hashlib.sha1()
    for b in batches:
        a = np.asarray(b)
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    if params is not None:
        for leaf in jax.tree_util.tree_leaves(params):
            h.update(np.asarray(leaf).tobytes())
    digest = h.hexdigest()[:12]
    return digest if method == "absmax" else f"{digest}:{method}"


class ProgramServeBase:
    """Compile-once, cache-keyed, schedule-carrying program serving."""

    def __init__(self, eng: EngineConfig, cache_capacity: int = 8,
                 scheduled: bool = True, cache: Optional[ProgramCache] = None,
                 schedule_policy: str = "asap"):
        self.eng = eng
        self.scheduled = scheduled
        self.schedule_policy = schedule_policy
        self.cache = (ProgramCache(cache_capacity, on_evict=self._on_evict)
                      if cache is None else cache)
        self._jitted: Dict[object, object] = {}

    # -- program cache -------------------------------------------------------

    def _variant(self, tag: str = "") -> str:
        v = schedule_variant(self.scheduled, self.schedule_policy)
        return f"{v}:{tag}" if tag else v

    def _program_key(self, model_cfg, calib_id: Optional[str],
                     tag: str = "") -> ProgramKey:
        return ProgramKey(model_cfg, self.eng, calib_id, self._variant(tag))

    def _cached_program(self, key: ProgramKey,
                        compile_fn: Callable[[], Program]) -> Program:
        """Cache hit, or compile-and-insert (counts hits/misses)."""
        return self.cache.get_or_compile(key, compile_fn)

    def _on_evict(self, key, program) -> None:
        self._jitted.pop(key, None)   # drop the evicted program's trace too

    # -- jitted executables --------------------------------------------------

    def _jitted_for(self, key, program: Program,
                    build: Callable[[Program], Callable]):
        """The program's jitted executable, traced once per cached program.

        A shared/injected cache evicts without calling this engine's
        _on_evict; prune traces for programs it no longer holds on every
        call (not just local misses) so the jit store stays bounded by the
        cache even when this engine's own working set is stable."""
        self._jitted = {k: f for k, f in self._jitted.items()
                        if k in self.cache}
        fn = self._jitted.get(key)
        if fn is None or fn[0] is not program:
            fn = (program, build(program))
            self._jitted[key] = fn
        return fn[1]

    # -- stats ---------------------------------------------------------------

    def cache_stats(self) -> Dict[str, object]:
        c = self.cache.stats
        return {
            "cache_hits": c.hits,
            "cache_misses": c.misses,
            "cache_evictions": c.evictions,
            "cache_hit_rate": c.hit_rate,
            "programs_cached": len(self.cache),
        }
