"""Shared program-serving base: compile -> ProgramCache -> jit -> schedule,
plus the continuous-batching SlotScheduler both engines feed the fabric
through.

Both serving engines ride this pipeline (the tentpole of the unified serve
path): `CNNServeEngine` serves registered CNN fleets as wave-batched
programs, and the LM `ServeEngine` serves transformer prefill + decode
programs from the same kind of keyed cache.  The base owns what they share:

  * the keyed LRU ProgramCache (own or injected/shared across engines),
    keyed by (model config, EngineConfig, calibration-id, variant);
  * the schedule variant (ASAP / ALAP leveling, or sequential);
  * the per-program jitted-executable store, pruned against the cache so a
    shared cache's evictions drop stale traces here too;
  * the SlotScheduler -- one slot-based request queue abstraction: the CNN
    engine keys slot groups by input shape (so models with identical
    shapes share wave buffers) and refills partial waves across arrival
    epochs; the LM engine draws prompt requests from it to refill finished
    decode slots between bursts;
  * cache statistics for the serving benchmarks.
"""
from __future__ import annotations

import hashlib
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.compiler.executor import (Program, execute_interleaved,
                                     schedule_variant)
from repro.compiler.schedule import merge_schedules
from repro.core.config import EngineConfig
from repro.core.program_cache import ProgramCache, ProgramKey
from repro.serve.mesh_exec import MeshExecutor


def calibration_digest(batches: Sequence, params=None,
                       method: str = "absmax",
                       granularity: str = "per_tensor",
                       weight_mode: str = "") -> str:
    """Stable id of the calibration inputs.  The recorded scales depend on
    the batches AND the float params (calibrate() runs the model) AND the
    calibrator method AND the scale granularity, so all four are digested:
    re-registering a model with new weights, new batches, a different
    calibrator (absmax vs percentile) or a different granularity
    (per-tensor vs per-channel) must miss the cache, not reuse stale
    activation scales.  `weight_mode` (engine.weight_mode: "" for int8
    weights, "w4g64" for int4 group-quantized) is appended so w4 and w8
    programs of the same model never share a cache line: the activation
    scales coincide, but the packed parameter trees the jitted executables
    close over do not."""
    h = hashlib.sha1()
    for b in batches:
        a = np.asarray(b)
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    if params is not None:
        for leaf in jax.tree_util.tree_leaves(params):
            h.update(np.asarray(leaf).tobytes())
    digest = h.hexdigest()[:12]
    if method != "absmax":
        digest = f"{digest}:{method}"
    if granularity != "per_tensor":
        digest = f"{digest}:pc"
    if weight_mode:
        digest = f"{digest}:{weight_mode}"
    return digest


# ---------------------------------------------------------------------------
# SlotScheduler: the shared continuous-batching request queue
# ---------------------------------------------------------------------------

@dataclass
class SlotStats:
    """Slot accounting across every dispatch the scheduler served."""
    submitted: int = 0
    dispatched: int = 0                  # requests handed out
    waves: int = 0                       # full-or-forced groups handed out
    padded_slots: int = 0                # empty slots in forced groups
    refilled_waves: int = 0              # groups spanning >1 arrival epoch
    locality_hits: int = 0               # requests placed in their model's
                                         # sticky device pool
    locality_misses: int = 0             # spilled into a foreign pool

    @property
    def fill_rate(self) -> float:
        slots = self.dispatched + self.padded_slots
        return self.dispatched / slots if slots else 0.0

    @property
    def locality_rate(self) -> float:
        placed = self.locality_hits + self.locality_misses
        return self.locality_hits / placed if placed else 0.0


@dataclass
class _Entry:
    ticket: int
    epoch: int
    payload: object
    affinity: Hashable = None            # pool-locality key (model name)


class SlotScheduler:
    """One slot-based request queue for every serving engine.

    Requests enter FIFO under a hashable group key (the CNN engine groups
    by input shape so same-shape models share wave buffers; the LM engine
    uses a single group whose takes refill finished decode slots).  A
    group's requests leave in waves of `slots`; a partial group is NOT
    dispatched until either later arrivals top it up (continuous batching)
    or the caller forces a drain (`take_wave(force=True)` pads, and the
    padding is what the fill-rate metric charges).  `epoch` advances on
    every dispatch round (`next_epoch`), so a dispatched wave whose entries
    span epochs is counted as a refilled wave -- slots that would have been
    pad under flush-per-arrival batching.

    With `pools` > 1 (one pool per mesh replica) a wave spans
    `pools * slots` rows and refill is LOCALITY-AWARE: each affinity key
    (the CNN engine passes the model name) gets a sticky home pool
    (round-robin on first sight), and `take_wave` packs that key's
    requests into its home pool's slot block first, spilling round-robin
    only when the block is full -- so a replica keeps seeing the model
    whose program rows it already executed (locality_hits / misses in
    stats).
    """

    def __init__(self, slots: int, pools: int = 1):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if pools < 1:
            raise ValueError("pools must be >= 1")
        self.slots = slots
        self.pools = pools
        self.stats = SlotStats()
        self.epoch = 0
        self._queues: "OrderedDict[Hashable, List[_Entry]]" = OrderedDict()
        self._home_pool: Dict[Tuple[Hashable, Hashable], int] = {}
        self._pool_rr: Dict[Hashable, int] = {}
        self._next_ticket = 0

    @property
    def wave_slots(self) -> int:
        """Rows per physical wave: one `slots`-sized pool per device."""
        return self.slots * self.pools

    def submit(self, group: Hashable, payload, affinity: Hashable = None
               ) -> int:
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queues.setdefault(group, []).append(
            _Entry(ticket, self.epoch, payload, affinity))
        self.stats.submitted += 1
        return ticket

    def home_pool(self, group: Hashable, affinity: Hashable) -> int:
        """The affinity key's sticky device pool within the group
        (assigned round-robin on first sight, stable afterwards)."""
        key = (group, affinity)
        pool = self._home_pool.get(key)
        if pool is None:
            rr = self._pool_rr.get(group, 0)
            pool = self._home_pool[key] = rr % self.pools
            self._pool_rr[group] = rr + 1
        return pool

    def _pack_pools(self, group: Hashable, entries: List[_Entry]
                    ) -> List[_Entry]:
        """Order a wave's entries so each affinity key's requests fill its
        home pool's slot block first (wave row i belongs to device pool
        i // slots).

        A single-pool scheduler places every request in its (only) home
        pool, so those placements count as locality hits -- otherwise
        locality_rate reads 0.0 on a 1-device mesh and jumps to ~1.0 at 2
        devices, breaking the monotone locality trend the fleet benchmark
        plots."""
        if self.pools <= 1:
            self.stats.locality_hits += len(entries)
            return entries
        by_aff: "OrderedDict[Hashable, List[_Entry]]" = OrderedDict()
        for e in entries:
            by_aff.setdefault(e.affinity, []).append(e)
        placed: List[Optional[_Entry]] = [None] * self.wave_slots
        homes: Dict[int, int] = {}      # final row -> home pool
        for aff, es in by_aff.items():
            home = self.home_pool(group, aff)
            i = 0
            for k in range(self.pools):
                base = ((home + k) % self.pools) * self.slots
                for row in range(base, base + self.slots):
                    if i >= len(es):
                        break
                    if placed[row] is None:
                        placed[row] = es[i]
                        homes[row] = home
                        i += 1
        # a partial (forced) wave compacts; full waves keep their rows
        out, hit_rows = [], []
        for row, e in enumerate(placed):
            if e is not None:
                hit_rows.append((len(out), homes[row]))
                out.append(e)
        for row, home in hit_rows:
            if row // self.slots == home:
                self.stats.locality_hits += 1
            else:
                self.stats.locality_misses += 1
        return out

    def next_epoch(self) -> None:
        """Mark a dispatch round boundary (a pump/flush or decode-burst
        edge); entries surviving it count as refill candidates."""
        self.epoch += 1

    def groups(self) -> List[Hashable]:
        return [g for g, q in self._queues.items() if q]

    def pending(self, group: Optional[Hashable] = None) -> int:
        if group is not None:
            return len(self._queues.get(group, []))
        return sum(len(q) for q in self._queues.values())

    def peek(self, group: Hashable) -> List[object]:
        """The group's queued payloads, FIFO order, without dispatching
        (the LM engine sizes its fixed prefill width from these)."""
        return [e.payload for e in self._queues.get(group, [])]

    def take(self, group: Hashable, limit: Optional[int] = None
             ) -> List[Tuple[int, object]]:
        """FIFO-pop up to `limit` (default: the slot count) requests -- the
        LM engine's slot-refill entry point."""
        q = self._queues.get(group, [])
        n = min(len(q), self.slots if limit is None else limit)
        taken, self._queues[group] = q[:n], q[n:]
        self.stats.dispatched += len(taken)
        if taken and len({e.epoch for e in taken}) > 1:
            self.stats.refilled_waves += 1
        return [(e.ticket, e.payload) for e in taken]

    def take_wave(self, group: Hashable, force: bool = False
                  ) -> Optional[List[Tuple[int, object]]]:
        """Pop one wave of exactly `wave_slots` (= pools * slots) requests,
        or None when the group is partial.  force=True drains a final
        partial wave (its empty slots are charged to padded_slots).  Multi-
        pool waves come back locality-packed (see _pack_pools)."""
        cap = self.wave_slots
        q = self._queues.get(group, [])
        if not q or (len(q) < cap and not force):
            return None
        taken, self._queues[group] = q[:cap], q[cap:]
        self.stats.dispatched += len(taken)
        self.stats.waves += 1
        self.stats.padded_slots += cap - len(taken)
        if len({e.epoch for e in taken}) > 1:
            self.stats.refilled_waves += 1
        taken = self._pack_pools(group, taken)
        return [(e.ticket, e.payload) for e in taken]


class LatencyTracker:
    """Per-request wall-clock latency, submit -> response materialization.

    Both engines clock every ticket at submit() and again at the response
    edge where its result becomes a host array, so the distribution
    measures what a caller actually waits -- queueing + batching + device
    time + the response-edge sync, not just kernel time.  percentiles()
    feeds the `latency_ms` block of BENCH_serve.json."""

    def __init__(self):
        self._open: Dict[int, float] = {}
        self.samples_ms: List[float] = []

    def submitted(self, ticket: int) -> None:
        self._open[ticket] = time.perf_counter()

    def completed(self, ticket: int) -> None:
        t0 = self._open.pop(ticket, None)
        if t0 is not None:
            self.samples_ms.append((time.perf_counter() - t0) * 1e3)

    def percentiles(self) -> Dict[str, float]:
        if not self.samples_ms:
            return {"n": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
        a = np.asarray(self.samples_ms)
        return {"n": int(a.size),
                "p50_ms": float(np.percentile(a, 50)),
                "p99_ms": float(np.percentile(a, 99)),
                "mean_ms": float(a.mean())}


class ProgramServeBase:
    """Compile-once, cache-keyed, schedule-carrying program serving."""

    def __init__(self, eng: EngineConfig, cache_capacity: int = 8,
                 scheduled: bool = True, cache: Optional[ProgramCache] = None,
                 schedule_policy: str = "asap", mesh=None):
        self.eng = eng
        self.scheduled = scheduled
        self.schedule_policy = schedule_policy
        self.cache = (ProgramCache(cache_capacity, on_evict=self._on_evict)
                      if cache is None else cache)
        self._jitted: Dict[object, object] = {}
        # mesh= routes all dispatch through the sharded executor; None
        # keeps the single-implicit-device behavior bit-for-bit
        self.mexec: Optional[MeshExecutor] = (
            mesh if isinstance(mesh, MeshExecutor) or mesh is None
            else MeshExecutor(mesh))
        self.latency = LatencyTracker()

    # -- program cache -------------------------------------------------------

    def _variant(self, tag: str = "") -> str:
        v = schedule_variant(self.scheduled, self.schedule_policy)
        return f"{v}:{tag}" if tag else v

    def _program_key(self, model_cfg, calib_id: Optional[str],
                     tag: str = "") -> ProgramKey:
        topo = self.mexec.topology if self.mexec is not None else None
        return ProgramKey(model_cfg, self.eng, calib_id, self._variant(tag),
                          mesh=topo)

    def _cached_program(self, key: ProgramKey,
                        compile_fn: Callable[[], Program]) -> Program:
        """Cache hit, or compile-and-insert (counts hits/misses)."""
        return self.cache.get_or_compile(key, compile_fn)

    def _on_evict(self, key, program) -> None:
        self._jitted.pop(key, None)   # drop the evicted program's trace too

    # -- jitted executables --------------------------------------------------

    def _jitted_for(self, key, program: Program,
                    build: Callable[[Program], Callable]):
        """The program's jitted executable, traced once per cached program.

        A shared/injected cache evicts without calling this engine's
        _on_evict; prune traces for programs it no longer holds on every
        call (not just local misses) so the jit store stays bounded by the
        cache even when this engine's own working set is stable."""
        self._jitted = {k: f for k, f in self._jitted.items()
                        if k in self.cache}
        fn = self._jitted.get(key)
        if fn is None or fn[0] is not program:
            fn = (program, build(program))
            self._jitted[key] = fn
        return fn[1]

    # -- stats ---------------------------------------------------------------

    def cache_stats(self) -> Dict[str, object]:
        c = self.cache.stats
        return {
            "cache_hits": c.hits,
            "cache_misses": c.misses,
            "cache_evictions": c.evictions,
            "cache_hit_rate": c.hit_rate,
            "programs_cached": len(self.cache),
        }


# ---------------------------------------------------------------------------
# FabricPump: cross-engine multi-tenant tick stream (f-CNNx co-mapping)
# ---------------------------------------------------------------------------

class FabricPump:
    """Drive a CNNServeEngine wave lane and a (dense) LM ServeEngine decode
    lane on ONE fabric tick stream.

    Each fabric tick advances both tenants: one CNN wave buffer and one LM
    decode step.  With `interleave=True` the tick is a SINGLE fused jitted
    call of executor.execute_interleaved -- the two programs' levels are
    zipped by schedule.merge_schedules (`merge_policy`), so the conv-heavy
    CNN levels fill the units the MISC-heavy LM decode levels leave idle
    and the host pays one dispatch instead of two.  With
    `interleave=False` the same tick issues the two programs as separate
    jitted calls -- identical work, serialized dispatch: the baseline leg
    of benchmarks/serve_mixed.py.  Outputs are bit-identical between the
    two modes and to isolated per-engine execution (the lanes share the
    dispatch stream, never dataflow).

    Served path: the LM lane replicates ServeEngine.run's dense
    continuous-batching loop (masked prefill + slot refill + decode
    bursts).  Paged-KV and speculative engines are rejected -- their steps
    are fused host-side loops of their own; only the plain DecodeStep
    program zips levels with a CNN wave.  Prefill ticks run un-fused (a
    prefill is a full forward program, not a per-tick recurrence)."""

    def __init__(self, cnn_engine, lm_engine, merge_policy: str = "cost",
                 interleave: bool = True):
        self.cnn = cnn_engine
        self.lm = lm_engine
        self.merge_policy = merge_policy
        self.interleave = interleave
        self.latency = LatencyTracker()
        # per CNN model: (cnn program, lm program, jitted step, merged) --
        # each registered model fuses its own program pair with the LM
        # decode step, so a multi-model run round-robins fused ticks
        # without re-tracing
        self._fused: Dict[str, tuple] = {}
        self.ticks = 0
        self.fused_ticks = 0
        self.solo_cnn_ticks = 0
        self.solo_lm_ticks = 0

    # -- merged schedule / fused step ----------------------------------------

    def merged_schedule(self, name: str, policy: Optional[str] = None):
        """The MergedSchedule aligning the named CNN program's levels with
        the LM DecodeStep program's (cost-priced; stats carry the modeled
        makespan and combined occupancy the mixed benchmark reports)."""
        from repro.compiler import cost as cost_lib
        prog_a = self.cnn.program_for(name)
        prog_b = self.lm.decode_program()
        times_a = cost_lib.default_node_times(prog_a.graph, prog_a.cfg,
                                              prog_a.kind)
        times_b = cost_lib.default_node_times(prog_b.graph, prog_b.cfg,
                                              prog_b.kind)
        return merge_schedules(prog_a.graph, prog_a.schedule,
                               prog_b.graph, prog_b.schedule,
                               times_a, times_b,
                               policy=policy or self.merge_policy)

    def _fused_step(self, name: str):
        """One jitted (CNN wave + LM decode step) executable, traced once
        per program pair (the _spec_jit pairing pattern), LM cache
        donated like the engine's own decode step."""
        prog_a = self.cnn.program_for(name)
        prog_b = self.lm.decode_program()
        ent = self._fused.get(name)
        if (ent is None or ent[0] is not prog_a or ent[1] is not prog_b):
            merged = self.merged_schedule(name)
            eng_a, eng_b = self.cnn.eng, self.lm.eng

            def step(qparams, buf, lparams, cache, cur):
                return execute_interleaved(prog_a, qparams, buf,
                                           prog_b, lparams, cache, cur,
                                           eng_a, eng_b, merged=merged)

            ent = (prog_a, prog_b,
                   jax.jit(step, donate_argnums=(3,)), merged)
            self._fused[name] = ent
        return ent[2]

    # -- the pump ------------------------------------------------------------

    def run(self, submissions, images: Optional[Sequence[np.ndarray]] = None,
            prompts: Optional[Sequence] = None, max_new_tokens: int = 8
            ) -> Tuple[List[np.ndarray], Dict[int, np.ndarray]]:
        """Serve CNN image traces and an LM prompt trace to completion on
        one tick stream.  Returns (cnn logits in submission order,
        {lm ticket: greedy token ids}).

        `submissions` is either a single model name (the legacy form:
        `run(name, images, prompts)`) or a {model name: [images...]} dict
        spanning several registered CNNs (`run({...}, prompts)`).  The
        dict form packs waves per input shape -- same-shape models share
        wave buffers, engine-style -- and drains the shape groups
        round-robin, so every tenant's waves interleave with the LM lane
        instead of one model monopolizing the early fused ticks.  Each
        model's program pair fuses with the LM decode step under its own
        merged schedule, cached across runs."""
        cnn, lm = self.cnn, self.lm
        if isinstance(submissions, str):
            subs = {submissions: list(images) if images is not None else []}
        else:
            subs = {name: list(imgs) for name, imgs in submissions.items()}
            if prompts is None:
                # dict form shifts the positionals: run(subs, prompts, ...)
                prompts = images
        prompts = list(prompts) if prompts is not None else []
        if getattr(lm, "paged", False):
            raise ValueError("FabricPump serves the dense KV path; paged "
                             "engines fuse their own prefill+merge steps")
        if getattr(lm, "draft_len", 0):
            raise ValueError("FabricPump serves plain decode; speculative "
                             "bursts are their own fused verify step")
        if not (lm.compiled and lm.compiled_decode):
            raise ValueError("FabricPump needs compiled LM programs "
                             "(lowering blockers: "
                             f"{lm.lowering_blockers()})")
        if cnn.mexec is not None or lm.mexec is not None:
            raise ValueError("FabricPump is single-device; drop mesh=")

        # -- submit both tenants' traces -------------------------------------
        cnn_tickets = [cnn.submit(name, img)
                       for name, imgs in subs.items() for img in imgs]
        lm_tickets = []
        for p in prompts:
            t = lm.submit(p, max_new_tokens)
            if not t and t != 0:
                raise ValueError(f"LM request rejected: {t}")
            lm_tickets.append(t)

        # -- CNN lane: pre-pack the wave buffers (zero-padded tail) ----------
        # Waves are keyed by INPUT SHAPE (the scheduler's grouping: models
        # with one shape share buffers) and drained ROUND-ROBIN across the
        # shape groups, so a multi-model trace alternates tenants on the
        # fused tick stream rather than finishing one model first.
        shapes: List[Tuple[int, int, int]] = []
        for name in subs:
            cfg = cnn._models[name].cfg
            shape = (cfg.input_hw, cfg.input_hw, cfg.input_ch)
            if shape not in shapes:
                shapes.append(shape)
        waves: List[Tuple[jax.Array,
                          Dict[str, List[Tuple[int, int]]]]] = []
        live = list(shapes)
        while live:
            for shape in list(live):     # one wave per live group per pass
                wave = cnn._sched.take_wave(shape, force=True)
                if wave is None:
                    live.remove(shape)
                    continue
                buf = np.zeros((cnn.wave_rows,) + shape, np.float32)
                slots_of: Dict[str, List[Tuple[int, int]]] = {}
                for slot, (ticket, (name, img)) in enumerate(wave):
                    buf[slot] = img
                    slots_of.setdefault(name, []).append((slot, ticket))
                waves.append((jnp.asarray(buf), slots_of))
                cnn.wave_stats.requests += len(wave)
                cnn.wave_stats.waves += 1
                cnn.wave_stats.padded += cnn.wave_rows - len(wave)
        cnn._sched.next_epoch()
        executors = {name: cnn._executor_for(name) for name in subs}

        def launch_model(name, buf, slots, in_flight):
            run_fn, qp = executors[name]
            in_flight.append((run_fn(qp, buf), slots))
            cnn.wave_stats.program_execs += 1
            cnn.execs_by_model[name] = cnn.execs_by_model.get(name, 0) + 1

        in_flight: List[Tuple[object, List[Tuple[int, int]]]] = []
        wave_i = 0

        # -- LM lane state (ServeEngine.run's dense loop) --------------------
        results: Dict[int, np.ndarray] = {}
        sched, B = lm._sched, lm.batch
        plen = lm.prefill_len
        if plen is None and sched.pending("lm"):
            plen = max(len(p) for p, _ in sched.peek("lm"))
        prefill_exec = lm._prefill_exec()
        decode_exec = lm._decode_exec()
        cache = lm._empty_cache()
        cache["pos"] = jnp.zeros((B,), jnp.int32)
        cur = jnp.zeros((B, 1), jnp.int32)
        tickets: List[Optional[int]] = [None] * B
        remaining = np.zeros(B, np.int64)
        start = np.zeros(B, np.int64)
        step = 0
        blocks: List[List] = []           # [start step, [B, w] device toks]
        block_np: Dict[int, np.ndarray] = {}

        def tokens_for(slot: int, lo: int, hi: int) -> np.ndarray:
            parts = []
            for s0, blk in blocks:
                w = blk.shape[1]
                if s0 + w <= lo or s0 >= hi:
                    continue
                arr = block_np.get(id(blk))
                if arr is None:
                    arr = block_np[id(blk)] = np.asarray(blk)
                parts.append(arr[slot, max(lo - s0, 0):min(hi - s0, w)])
            return (np.concatenate(parts).astype(np.int32) if parts
                    else np.zeros(0, np.int32))

        def decode_tick(cur, cache):
            """One fabric tick: one LM decode step, co-scheduled with the
            next CNN wave when one is pending.  A multi-model wave fuses
            ONE model's execution with the decode step (the fused call zips
            exactly one program pair); the wave's same-shape foreign models
            launch solo on the same tick, engine-style."""
            nonlocal wave_i
            self.ticks += 1
            if wave_i < len(waves):
                buf, slots_of = waves[wave_i]
                wave_i += 1
                names = list(slots_of)
                fused_with = None
                logits_b = None
                if self.interleave:
                    fused_with = names[0]
                    run_fn, qp = executors[fused_with]
                    logits_a, logits_b, cache = self._fused_step(fused_with)(
                        qp, buf, lm.params, cache, cur)
                    in_flight.append((logits_a, slots_of[fused_with]))
                    cnn.wave_stats.program_execs += 1
                    cnn.execs_by_model[fused_with] = (
                        cnn.execs_by_model.get(fused_with, 0) + 1)
                    self.fused_ticks += 1
                for name in names:
                    if name != fused_with:
                        launch_model(name, buf, slots_of[name], in_flight)
                if logits_b is not None:
                    return logits_b, cache
            else:
                self.solo_lm_ticks += 1
            logits_b, cache = decode_exec(lm.params, cache, cur)
            return logits_b, cache

        # -- continuous batching over fabric ticks ---------------------------
        while True:
            free = [i for i in range(B) if remaining[i] == 0]
            if free and sched.pending("lm"):
                taken = sched.take("lm", limit=len(free))
                if taken:
                    toks = np.zeros((B, plen), np.int32)
                    mask = np.zeros(B, bool)
                    for slot, (ticket, (prompt, mnt)) in zip(free, taken):
                        if len(prompt) > plen:
                            raise ValueError(
                                f"prompt of length {len(prompt)} exceeds "
                                f"the run's fixed prefill width {plen}")
                        toks[slot, plen - len(prompt):] = prompt
                        mask[slot] = True
                        if tickets[slot] is not None:
                            lm.serve_stats.slot_refills += 1
                        tickets[slot] = ticket
                        remaining[slot] = mnt
                        start[slot] = step
                    jmask = jnp.asarray(mask)
                    logits, fresh = prefill_exec(
                        lm.params, lm._empty_cache(),
                        {"tokens": jnp.asarray(toks)})
                    cache = lm.jmerge(cache, fresh, jmask)
                    first = jnp.argmax(logits[:, -1, :], axis=-1)
                    cur = jnp.where(jmask[:, None], first[:, None], cur
                                    ).astype(jnp.int32)
                    lm.serve_stats.prefill_calls += 1
                    lm.serve_stats.requests += len(taken)
                    sched.next_epoch()
                    self.ticks += 1

            act = [i for i in range(B) if remaining[i] > 0]
            if not act:
                if sched.pending("lm"):
                    continue
                break
            burst = int(min(lm.decode_burst,
                            min(remaining[i] for i in act)))
            cols = []
            for _ in range(burst):
                cols.append(cur)
                logits, cache = decode_tick(cur, cache)
                cur = jnp.argmax(logits[:, -1, :], axis=-1)[:, None
                                                            ].astype(jnp.int32)
                lm.serve_stats.decode_steps += 1
                lm.serve_stats.active_slot_steps += len(act)
            blocks.append([step, cols[0] if burst == 1
                           else jnp.concatenate(cols, axis=1)])
            step += burst
            finished = False
            for i in act:
                remaining[i] -= burst
                if remaining[i] == 0:     # response edge for this ticket
                    results[tickets[i]] = tokens_for(i, int(start[i]), step)
                    lm.latency.completed(tickets[i])
                    self.latency.samples_ms.append(
                        lm.latency.samples_ms[-1])
                    finished = True
            if finished:
                live = [int(start[i]) for i in range(B) if remaining[i] > 0]
                lo = min(live) if live else step
                keep = [b for b in blocks if b[0] + b[1].shape[1] > lo]
                kept_ids = {id(b[1]) for b in keep}
                for b in blocks:
                    if id(b[1]) not in kept_ids:
                        block_np.pop(id(b[1]), None)
                blocks = keep

        # -- drain leftover CNN waves (LM lane dry) --------------------------
        while wave_i < len(waves):
            buf, slots_of = waves[wave_i]
            wave_i += 1
            for name, slots in slots_of.items():
                launch_model(name, buf, slots, in_flight)
            self.ticks += 1
            self.solo_cnn_ticks += 1

        # -- CNN response edge: one host sync per wave execution -------------
        cnn_results: Dict[int, np.ndarray] = {}
        for dev_logits, slots in in_flight:
            logits = np.asarray(dev_logits)
            for slot, ticket in slots:
                cnn_results[ticket] = logits[slot]
                cnn.latency.completed(ticket)
                self.latency.samples_ms.append(
                    cnn.latency.samples_ms[-1])
        return ([cnn_results[t] for t in cnn_tickets],
                {t: results[t] for t in lm_tickets})

    def stats(self) -> Dict[str, object]:
        out = {
            "ticks": self.ticks,
            "fused_ticks": self.fused_ticks,
            "solo_cnn_ticks": self.solo_cnn_ticks,
            "solo_lm_ticks": self.solo_lm_ticks,
            "interleave": self.interleave,
            "merge_policy": self.merge_policy,
            "latency_ms": self.latency.percentiles(),
        }
        if self._fused:
            # legacy single-model key: the first fused pair's merged stats;
            # the per-model dict carries every tenant's schedule evidence
            first = next(iter(self._fused.values()))
            out["merged"] = dict(first[3].stats)
            out["merged_by_model"] = {name: dict(ent[3].stats)
                                      for name, ent in self._fused.items()}
        return out
