"""Shared program-serving base: compile -> ProgramCache -> jit -> schedule,
plus the continuous-batching SlotScheduler both engines feed the fabric
through.

Both serving engines ride this pipeline (the tentpole of the unified serve
path): `CNNServeEngine` serves registered CNN fleets as wave-batched
programs, and the LM `ServeEngine` serves transformer prefill + decode
programs from the same kind of keyed cache.  The base owns what they share:

  * the keyed LRU ProgramCache (own or injected/shared across engines),
    keyed by (model config, EngineConfig, calibration-id, variant);
  * the schedule variant (ASAP / ALAP leveling, or sequential);
  * the per-program jitted-executable store, pruned against the cache so a
    shared cache's evictions drop stale traces here too;
  * the SlotScheduler -- one slot-based request queue abstraction: the CNN
    engine keys slot groups by input shape (so models with identical
    shapes share wave buffers) and refills partial waves across arrival
    epochs; the LM engine draws prompt requests from it to refill finished
    decode slots between bursts;
  * cache statistics for the serving benchmarks.
"""
from __future__ import annotations

import hashlib
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.compiler.executor import Program, schedule_variant
from repro.core.config import EngineConfig
from repro.core.program_cache import ProgramCache, ProgramKey
from repro.serve.mesh_exec import MeshExecutor


def calibration_digest(batches: Sequence, params=None,
                       method: str = "absmax",
                       granularity: str = "per_tensor",
                       weight_mode: str = "") -> str:
    """Stable id of the calibration inputs.  The recorded scales depend on
    the batches AND the float params (calibrate() runs the model) AND the
    calibrator method AND the scale granularity, so all four are digested:
    re-registering a model with new weights, new batches, a different
    calibrator (absmax vs percentile) or a different granularity
    (per-tensor vs per-channel) must miss the cache, not reuse stale
    activation scales.  `weight_mode` (engine.weight_mode: "" for int8
    weights, "w4g64" for int4 group-quantized) is appended so w4 and w8
    programs of the same model never share a cache line: the activation
    scales coincide, but the packed parameter trees the jitted executables
    close over do not."""
    h = hashlib.sha1()
    for b in batches:
        a = np.asarray(b)
        h.update(str(a.shape).encode())
        h.update(a.tobytes())
    if params is not None:
        for leaf in jax.tree_util.tree_leaves(params):
            h.update(np.asarray(leaf).tobytes())
    digest = h.hexdigest()[:12]
    if method != "absmax":
        digest = f"{digest}:{method}"
    if granularity != "per_tensor":
        digest = f"{digest}:pc"
    if weight_mode:
        digest = f"{digest}:{weight_mode}"
    return digest


# ---------------------------------------------------------------------------
# SlotScheduler: the shared continuous-batching request queue
# ---------------------------------------------------------------------------

@dataclass
class SlotStats:
    """Slot accounting across every dispatch the scheduler served."""
    submitted: int = 0
    dispatched: int = 0                  # requests handed out
    waves: int = 0                       # full-or-forced groups handed out
    padded_slots: int = 0                # empty slots in forced groups
    refilled_waves: int = 0              # groups spanning >1 arrival epoch
    locality_hits: int = 0               # requests placed in their model's
                                         # sticky device pool
    locality_misses: int = 0             # spilled into a foreign pool

    @property
    def fill_rate(self) -> float:
        slots = self.dispatched + self.padded_slots
        return self.dispatched / slots if slots else 0.0

    @property
    def locality_rate(self) -> float:
        placed = self.locality_hits + self.locality_misses
        return self.locality_hits / placed if placed else 0.0


@dataclass
class _Entry:
    ticket: int
    epoch: int
    payload: object
    affinity: Hashable = None            # pool-locality key (model name)


class SlotScheduler:
    """One slot-based request queue for every serving engine.

    Requests enter FIFO under a hashable group key (the CNN engine groups
    by input shape so same-shape models share wave buffers; the LM engine
    uses a single group whose takes refill finished decode slots).  A
    group's requests leave in waves of `slots`; a partial group is NOT
    dispatched until either later arrivals top it up (continuous batching)
    or the caller forces a drain (`take_wave(force=True)` pads, and the
    padding is what the fill-rate metric charges).  `epoch` advances on
    every dispatch round (`next_epoch`), so a dispatched wave whose entries
    span epochs is counted as a refilled wave -- slots that would have been
    pad under flush-per-arrival batching.

    With `pools` > 1 (one pool per mesh replica) a wave spans
    `pools * slots` rows and refill is LOCALITY-AWARE: each affinity key
    (the CNN engine passes the model name) gets a sticky home pool
    (round-robin on first sight), and `take_wave` packs that key's
    requests into its home pool's slot block first, spilling round-robin
    only when the block is full -- so a replica keeps seeing the model
    whose program rows it already executed (locality_hits / misses in
    stats).
    """

    def __init__(self, slots: int, pools: int = 1):
        if slots < 1:
            raise ValueError("slots must be >= 1")
        if pools < 1:
            raise ValueError("pools must be >= 1")
        self.slots = slots
        self.pools = pools
        self.stats = SlotStats()
        self.epoch = 0
        self._queues: "OrderedDict[Hashable, List[_Entry]]" = OrderedDict()
        self._home_pool: Dict[Tuple[Hashable, Hashable], int] = {}
        self._pool_rr: Dict[Hashable, int] = {}
        self._next_ticket = 0

    @property
    def wave_slots(self) -> int:
        """Rows per physical wave: one `slots`-sized pool per device."""
        return self.slots * self.pools

    def submit(self, group: Hashable, payload, affinity: Hashable = None
               ) -> int:
        ticket = self._next_ticket
        self._next_ticket += 1
        self._queues.setdefault(group, []).append(
            _Entry(ticket, self.epoch, payload, affinity))
        self.stats.submitted += 1
        return ticket

    def home_pool(self, group: Hashable, affinity: Hashable) -> int:
        """The affinity key's sticky device pool within the group
        (assigned round-robin on first sight, stable afterwards)."""
        key = (group, affinity)
        pool = self._home_pool.get(key)
        if pool is None:
            rr = self._pool_rr.get(group, 0)
            pool = self._home_pool[key] = rr % self.pools
            self._pool_rr[group] = rr + 1
        return pool

    def _pack_pools(self, group: Hashable, entries: List[_Entry]
                    ) -> List[_Entry]:
        """Order a wave's entries so each affinity key's requests fill its
        home pool's slot block first (wave row i belongs to device pool
        i // slots).

        A single-pool scheduler places every request in its (only) home
        pool, so those placements count as locality hits -- otherwise
        locality_rate reads 0.0 on a 1-device mesh and jumps to ~1.0 at 2
        devices, breaking the monotone locality trend the fleet benchmark
        plots."""
        if self.pools <= 1:
            self.stats.locality_hits += len(entries)
            return entries
        by_aff: "OrderedDict[Hashable, List[_Entry]]" = OrderedDict()
        for e in entries:
            by_aff.setdefault(e.affinity, []).append(e)
        placed: List[Optional[_Entry]] = [None] * self.wave_slots
        homes: Dict[int, int] = {}      # final row -> home pool
        for aff, es in by_aff.items():
            home = self.home_pool(group, aff)
            i = 0
            for k in range(self.pools):
                base = ((home + k) % self.pools) * self.slots
                for row in range(base, base + self.slots):
                    if i >= len(es):
                        break
                    if placed[row] is None:
                        placed[row] = es[i]
                        homes[row] = home
                        i += 1
        # a partial (forced) wave compacts; full waves keep their rows
        out, hit_rows = [], []
        for row, e in enumerate(placed):
            if e is not None:
                hit_rows.append((len(out), homes[row]))
                out.append(e)
        for row, home in hit_rows:
            if row // self.slots == home:
                self.stats.locality_hits += 1
            else:
                self.stats.locality_misses += 1
        return out

    def next_epoch(self) -> None:
        """Mark a dispatch round boundary (a pump/flush or decode-burst
        edge); entries surviving it count as refill candidates."""
        self.epoch += 1

    def groups(self) -> List[Hashable]:
        return [g for g, q in self._queues.items() if q]

    def pending(self, group: Optional[Hashable] = None) -> int:
        if group is not None:
            return len(self._queues.get(group, []))
        return sum(len(q) for q in self._queues.values())

    def peek(self, group: Hashable) -> List[object]:
        """The group's queued payloads, FIFO order, without dispatching
        (the LM engine sizes its fixed prefill width from these)."""
        return [e.payload for e in self._queues.get(group, [])]

    def take(self, group: Hashable, limit: Optional[int] = None
             ) -> List[Tuple[int, object]]:
        """FIFO-pop up to `limit` (default: the slot count) requests -- the
        LM engine's slot-refill entry point."""
        q = self._queues.get(group, [])
        n = min(len(q), self.slots if limit is None else limit)
        taken, self._queues[group] = q[:n], q[n:]
        self.stats.dispatched += len(taken)
        if taken and len({e.epoch for e in taken}) > 1:
            self.stats.refilled_waves += 1
        return [(e.ticket, e.payload) for e in taken]

    def take_wave(self, group: Hashable, force: bool = False
                  ) -> Optional[List[Tuple[int, object]]]:
        """Pop one wave of exactly `wave_slots` (= pools * slots) requests,
        or None when the group is partial.  force=True drains a final
        partial wave (its empty slots are charged to padded_slots).  Multi-
        pool waves come back locality-packed (see _pack_pools)."""
        cap = self.wave_slots
        q = self._queues.get(group, [])
        if not q or (len(q) < cap and not force):
            return None
        taken, self._queues[group] = q[:cap], q[cap:]
        self.stats.dispatched += len(taken)
        self.stats.waves += 1
        self.stats.padded_slots += cap - len(taken)
        if len({e.epoch for e in taken}) > 1:
            self.stats.refilled_waves += 1
        taken = self._pack_pools(group, taken)
        return [(e.ticket, e.payload) for e in taken]


class LatencyTracker:
    """Per-request wall-clock latency, submit -> response materialization.

    Both engines clock every ticket at submit() and again at the response
    edge where its result becomes a host array, so the distribution
    measures what a caller actually waits -- queueing + batching + device
    time + the response-edge sync, not just kernel time.  percentiles()
    feeds the `latency_ms` block of BENCH_serve.json."""

    def __init__(self):
        self._open: Dict[int, float] = {}
        self.samples_ms: List[float] = []

    def submitted(self, ticket: int) -> None:
        self._open[ticket] = time.perf_counter()

    def completed(self, ticket: int) -> None:
        t0 = self._open.pop(ticket, None)
        if t0 is not None:
            self.samples_ms.append((time.perf_counter() - t0) * 1e3)

    def percentiles(self) -> Dict[str, float]:
        if not self.samples_ms:
            return {"n": 0, "p50_ms": 0.0, "p99_ms": 0.0, "mean_ms": 0.0}
        a = np.asarray(self.samples_ms)
        return {"n": int(a.size),
                "p50_ms": float(np.percentile(a, 50)),
                "p99_ms": float(np.percentile(a, 99)),
                "mean_ms": float(a.mean())}


class ProgramServeBase:
    """Compile-once, cache-keyed, schedule-carrying program serving."""

    def __init__(self, eng: EngineConfig, cache_capacity: int = 8,
                 scheduled: bool = True, cache: Optional[ProgramCache] = None,
                 schedule_policy: str = "asap", mesh=None):
        self.eng = eng
        self.scheduled = scheduled
        self.schedule_policy = schedule_policy
        self.cache = (ProgramCache(cache_capacity, on_evict=self._on_evict)
                      if cache is None else cache)
        self._jitted: Dict[object, object] = {}
        # mesh= routes all dispatch through the sharded executor; None
        # keeps the single-implicit-device behavior bit-for-bit
        self.mexec: Optional[MeshExecutor] = (
            mesh if isinstance(mesh, MeshExecutor) or mesh is None
            else MeshExecutor(mesh))
        self.latency = LatencyTracker()

    # -- program cache -------------------------------------------------------

    def _variant(self, tag: str = "") -> str:
        v = schedule_variant(self.scheduled, self.schedule_policy)
        return f"{v}:{tag}" if tag else v

    def _program_key(self, model_cfg, calib_id: Optional[str],
                     tag: str = "") -> ProgramKey:
        topo = self.mexec.topology if self.mexec is not None else None
        return ProgramKey(model_cfg, self.eng, calib_id, self._variant(tag),
                          mesh=topo)

    def _cached_program(self, key: ProgramKey,
                        compile_fn: Callable[[], Program]) -> Program:
        """Cache hit, or compile-and-insert (counts hits/misses)."""
        return self.cache.get_or_compile(key, compile_fn)

    def _on_evict(self, key, program) -> None:
        self._jitted.pop(key, None)   # drop the evicted program's trace too

    # -- jitted executables --------------------------------------------------

    def _jitted_for(self, key, program: Program,
                    build: Callable[[Program], Callable]):
        """The program's jitted executable, traced once per cached program.

        A shared/injected cache evicts without calling this engine's
        _on_evict; prune traces for programs it no longer holds on every
        call (not just local misses) so the jit store stays bounded by the
        cache even when this engine's own working set is stable."""
        self._jitted = {k: f for k, f in self._jitted.items()
                        if k in self.cache}
        fn = self._jitted.get(key)
        if fn is None or fn[0] is not program:
            fn = (program, build(program))
            self._jitted[key] = fn
        return fn[1]

    # -- stats ---------------------------------------------------------------

    def cache_stats(self) -> Dict[str, object]:
        c = self.cache.stats
        return {
            "cache_hits": c.hits,
            "cache_misses": c.misses,
            "cache_evictions": c.evictions,
            "cache_hit_rate": c.hit_rate,
            "programs_cached": len(self.cache),
        }
