"""Sharded multi-device placement for the serving tier.

`launch/mesh.py` builds production meshes that, until now, only the
training/launch path consumed.  This module is the serving-side consumer:
it places compiled engine programs on a ("data", "model") device mesh so
one engine serves from every chip at once.

Two placement regimes, both bit-identical to single-device execution:

  * data-parallel CNN waves -- the wave buffer shards over the batch axis
    (`NamedSharding(mesh, P("data"))` via the same `batch_axes` /
    divisibility rule as `launch.mesh.act_pspec`) while the folded weight
    tree replicates.  The static-int8 path accumulates GEMMs in int32, so
    per-replica partial batches reproduce the single-device rows exactly
    (the sharded-parity property test pins this zoo-wide).

  * tensor-parallel LM decode bursts -- LinearOp weights shard over the
    "model" axis, reusing `models.params.resolve_pspec` for the logical
    tp axes, with one serving-specific restriction: attention projections
    shard only at WHOLE-HEAD granularity.  Splitting inside a kv head
    would shard the attention score contraction over head_dim and reorder
    its float reduction (measured: ~4e-1 logit drift on a reduced arch
    whose single 32-dim kv head was split 4 ways -- greedy decode then
    diverges from token 1).  Column-parallel wq/wu/wg, row-parallel
    wo/wd (int8 GEMMs, int32 partial sums) and vocab-sharded embeddings
    are exact, so everything else shards whenever divisible.

`MeshTopology` is the hashable mesh descriptor `ProgramKey` carries, so
programs traced for different meshes never collide in a shared
ProgramCache.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.quant import Q4Tensor, QTensor
from repro.launch import mesh as mesh_lib
from repro.models.params import resolve_pspec

__all__ = ["MeshTopology", "MeshExecutor", "make_serve_mesh",
           "tp_shardable", "lm_tp_pspec"]


@dataclass(frozen=True)
class MeshTopology:
    """Hashable mesh descriptor: device count + axis shape.  This is the
    ProgramKey component -- two engines serving the same model on meshes
    of different shape must not share a cached program/trace."""
    axes: Tuple[Tuple[str, int], ...]

    @classmethod
    def from_mesh(cls, mesh) -> "MeshTopology":
        return cls(tuple((str(a), int(mesh.shape[a]))
                         for a in mesh.axis_names))

    @property
    def devices(self) -> int:
        n = 1
        for _, size in self.axes:
            n *= size
        return n

    def size(self, axis: str) -> int:
        return dict(self.axes).get(axis, 1)

    def __str__(self) -> str:
        shape = "x".join(str(s) for _, s in self.axes)
        names = ",".join(a for a, _ in self.axes)
        return f"mesh[{shape};{names}]"


def make_serve_mesh(n_data: Optional[int] = None, n_model: int = 1) -> Mesh:
    """A ("data", "model") serving mesh over the first n_data*n_model
    local devices (default: all of them on the data axis)."""
    devs = jax.devices()
    if n_data is None:
        n_data = max(1, len(devs) // max(1, n_model))
    need = n_data * n_model
    if need > len(devs):
        raise ValueError(f"mesh ({n_data}x{n_model}) needs {need} devices, "
                         f"have {len(devs)}")
    grid = np.array(devs[:need]).reshape(n_data, n_model)
    return Mesh(grid, ("data", "model"))


# -- tensor-parallel LM placement -------------------------------------------

# Serving-TP logical axes per LM param name (params replicate over "data";
# only the "tp" -> "model" dimension shards).  resolve_pspec drops any
# non-divisible dim, so these are upper bounds.
_TP_AXES: Dict[str, Tuple] = {
    "wq": (None, "tp"), "wk": (None, "tp"), "wv": (None, "tp"),
    "wo": ("tp", None),
    "wu": (None, "tp"), "wg": (None, "tp"), "wd": ("tp", None),
    "embed": ("tp", None),            # vocab rows; tied head stays exact
    "head": (None, "tp"),             # vocab columns
}


def tp_shardable(name: str, arch, tp: int) -> bool:
    """The whole-head granularity guard.  Attention projections may only
    shard when the model axis divides their HEAD count -- a shard boundary
    inside one head's head_dim slice would shard the score/value
    contraction and change the attention float math (not bit-identical).
    MLP and embedding dims carry no such structure."""
    if tp <= 1:
        return False
    if name in ("wq", "wo"):
        return arch.n_heads % tp == 0
    if name in ("wk", "wv"):
        return arch.n_kv_heads % tp == 0
    return name in _TP_AXES


def lm_tp_pspec(name: str, shape, arch, mesh) -> P:
    """PartitionSpec for one LM param under serving TP: the logical tp
    axes via resolve_pspec, gated by the whole-head rule.  Unknown names
    (norms, biases, SSM mixers) replicate -- always exact."""
    tp = dict(zip(mesh.axis_names,
                  [mesh.shape[a] for a in mesh.axis_names])).get("model", 1)
    if not tp_shardable(name, arch, tp):
        return P()
    return resolve_pspec(mesh, shape, _TP_AXES[name])


class MeshExecutor:
    """Places wave buffers, param trees, and decode state on a serving
    mesh.  Engines route all device placement through this object; with no
    executor they behave exactly as before (single implicit device)."""

    def __init__(self, mesh: Mesh):
        self.mesh = mesh
        self.topology = MeshTopology.from_mesh(mesh)
        self._replicated = NamedSharding(mesh, P())

    # -- shape --------------------------------------------------------------

    @property
    def n_devices(self) -> int:
        return self.topology.devices

    @property
    def n_data(self) -> int:
        return self.topology.size("data") * self.topology.size("pod")

    @property
    def n_model(self) -> int:
        return self.topology.size("model")

    # -- placement ----------------------------------------------------------

    def replicate(self, tree):
        """Every leaf replicated across the mesh (QTensor leaves are
        pytrees of (q, scale); both replicate)."""
        return jax.device_put(tree, self._replicated)

    def batch_pspec(self, batch: int) -> P:
        """Batch-axis spec for a wave buffer: the act_pspec rule -- shard
        over the data axes when divisible, replicate otherwise."""
        dp = mesh_lib.batch_axes(self.mesh)
        return P(dp) if batch % max(self.n_data, 1) == 0 else P()

    def place_wave(self, buf: jax.Array) -> jax.Array:
        """Shard a [rows, ...] wave buffer over the data axis: each
        replica holds its own slot-pool's rows."""
        return jax.device_put(
            buf, NamedSharding(self.mesh, self.batch_pspec(buf.shape[0])))

    def _place_named(self, name: Optional[str], leaf, arch):
        spec = lm_tp_pspec(name, _leaf_shape(leaf), arch, self.mesh) \
            if name else P()
        sh = NamedSharding(self.mesh, spec)
        if isinstance(leaf, QTensor):
            # int8 payload shards; the (scalar / per-channel) scale is
            # tiny -- replicate it, elementwise requant stays exact
            return QTensor(jax.device_put(leaf.q, sh),
                           jax.device_put(leaf.scale, self._replicated)), spec
        if isinstance(leaf, Q4Tensor):
            # The int4 packing interleaves K-row pairs into one uint8 and
            # groups K rows per scale row, so a K shard ("model" on dim 0,
            # the row-parallel wo/wd plan) would cut through nibble pairs
            # and scale groups -- those weights replicate instead.  Column
            # (N) shards cut cleanly: packed [K//2, N], scale and zero
            # [G, N] all carry N last, and every per-column output is
            # computed from one shard's columns alone.
            if len(spec) > 0 and spec[0] is not None:
                spec = P()
                sh = self._replicated
            return Q4Tensor(jax.device_put(leaf.packed, sh),
                            jax.device_put(leaf.scale, sh),
                            jax.device_put(leaf.zero, sh)), spec
        return jax.device_put(leaf, sh), spec

    def place_lm_params(self, arch, params):
        """Tensor-parallel placement of an LM param tree by leaf name.
        Returns (placed tree, report) where the report counts sharded vs
        replicated leaves -- the engine surfaces it in stats()."""
        report = {"tp_sharded": 0, "tp_replicated": 0, "tp_axis": self.n_model}

        def rec(node, name=None):
            if isinstance(node, dict):
                return {k: rec(v, k) for k, v in node.items()}
            # QTensor/Q4Tensor are NamedTuples: placement leaves, not
            # containers
            if isinstance(node, (list, tuple)) \
                    and not isinstance(node, (QTensor, Q4Tensor)):
                return type(node)(rec(v, name) for v in node)
            placed, spec = self._place_named(name, node, arch)
            if spec == P():
                report["tp_replicated"] += 1
            else:
                report["tp_sharded"] += 1
            return placed

        return rec(params), report

    def describe(self) -> Dict[str, object]:
        return {"devices": self.n_devices, "data": self.n_data,
                "model": self.n_model, "topology": str(self.topology)}


def _leaf_shape(leaf):
    if isinstance(leaf, QTensor):
        return tuple(leaf.q.shape)
    if isinstance(leaf, Q4Tensor):
        return tuple(leaf.shape)          # logical [K, N], not packed [K//2, N]
    return tuple(np.shape(leaf))
