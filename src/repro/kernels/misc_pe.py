"""MISC core: fused elementwise / pooling epilogues.

Paper (Section III-A, C6): element-wise addition, pooling and activations run
on AIE cores instead of PL DSPs, saving 95.8% of DSP slices.  The TPU
analogue of "keep it in the compute array" is "keep it in VMEM in one fused
kernel" -- a residual add + requant that would otherwise be two HBM
round-trips becomes a single pass.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import act_fn
from repro.kernels._pallas_compat import compiler_params


def _add_kernel(a_ref, b_ref, o_ref, *, sa: float, sb: float, act: str,
                out_scale: Optional[float]):
    x = a_ref[...].astype(jnp.float32) * sa + b_ref[...].astype(jnp.float32) * sb
    x = act_fn(act)(x)
    if out_scale is not None:
        x = jnp.clip(jnp.round(x / out_scale), -127, 127)
    o_ref[...] = x.astype(o_ref.dtype)


def misc_add(a: jax.Array, b: jax.Array, sa: float = 1.0, sb: float = 1.0,
             act: str = "none", out_scale: Optional[float] = None,
             out_dtype=jnp.float32, *, block: int = 1024,
             interpret: bool = False) -> jax.Array:
    """Fused scaled add (+ activation + requant). a, b same shape."""
    shape = a.shape
    n = 1
    for d in shape:
        n *= d
    # Flatten to [rows, 128] lanes; pad rows to the block size.
    lanes = 128
    rows = (n + lanes - 1) // lanes
    rows_p = ((rows + block - 1) // block) * block
    af = jnp.pad(a.reshape(-1), (0, rows_p * lanes - n)).reshape(rows_p, lanes)
    bf = jnp.pad(b.reshape(-1), (0, rows_p * lanes - n)).reshape(rows_p, lanes)
    odt = jnp.int8 if out_scale is not None else out_dtype
    out = pl.pallas_call(
        functools.partial(_add_kernel, sa=sa, sb=sb, act=act,
                          out_scale=out_scale),
        grid=(rows_p // block,),
        in_specs=[pl.BlockSpec((block, lanes), lambda i: (i, 0)),
                  pl.BlockSpec((block, lanes), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block, lanes), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows_p, lanes), odt),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(af, bf)
    return out.reshape(-1)[:n].reshape(shape)


def _avgpool_kernel(x_ref, o_ref, *, window: int, stride: int,
                    ho: int, wo: int):
    x = x_ref[0]
    acc = jnp.zeros((ho, wo, x.shape[-1]), jnp.float32)
    for kh in range(window):
        for kw in range(window):
            xs = jax.lax.slice(
                x, (kh, kw, 0),
                (kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1,
                 x.shape[-1]),
                (stride, stride, 1))
            acc = acc + xs.astype(jnp.float32)
    o_ref[0] = (acc / (window * window)).astype(o_ref.dtype)


def avgpool2d(x: jax.Array, window: int, stride: int,
              out_dtype=jnp.float32, *, bc: int = 128,
              interpret: bool = False) -> jax.Array:
    """[N, H, W, C] VALID average pool (C % bc == 0)."""
    n, h, w, c = x.shape
    assert c % bc == 0
    ho = (h - window) // stride + 1
    wo = (w - window) // stride + 1
    return pl.pallas_call(
        functools.partial(_avgpool_kernel, window=window, stride=stride,
                          ho=ho, wo=wo),
        grid=(n, c // bc),
        in_specs=[pl.BlockSpec((1, h, w, bc), lambda i, j: (i, 0, 0, j))],
        out_specs=pl.BlockSpec((1, ho, wo, bc), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, c), out_dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x)
