"""Flash attention (prefill) as a Pallas TPU kernel -- beyond-paper.

The paper's cascade insight (partial results live in on-array scratch and
never round-trip HBM) applied to attention: the running (max, sum, acc)
online-softmax state is VMEM scratch swept along the KV grid axis, exactly
like conv_pe's PsumStack along the IC axis.

Layout: q [BH, L, D], k/v [BH, S, D] (heads flattened into the batch dim by
the ops wrapper).  Grid (BH, L/bq, S/bkv) with the KV axis "arbitrary"
(revolving accumulator).  Causal masking with optional logit softcap;
fully-masked blocks still execute (the masked-rectangle baseline -- the
triangle-skip variant lives in the jnp path where it is differentiable).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels._pallas_compat import compiler_params

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
            *, nk: int, bq: int, bkv: int, scale: float, causal: bool,
            softcap: float, seq_q: int, seq_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                 # [bq, D]
    k = k_ref[0].astype(jnp.float32)                 # [bkv, D]
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0) \
        + (seq_kv - seq_q)                           # align ends (prefill)
    kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = kpos < seq_kv
    if causal:
        mask = mask & (kpos <= qpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: Optional[float] = None,
                    softcap: float = 0.0,
                    bq: int = 128, bkv: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: [BH, L, D]; k, v: [BH, S, D].  L, S padded to block multiples by
    the wrapper (ops.flash_mha)."""
    bh, l, d = q.shape
    s = k.shape[1]
    assert l % bq == 0 and s % bkv == 0, (l, s, bq, bkv)
    scale = scale if scale is not None else d ** -0.5
    nk = s // bkv
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, bq=bq, bkv=bkv, scale=scale,
                          causal=causal, softcap=softcap, seq_q=l, seq_kv=s),
        grid=(bh, l // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, l, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),        # running max
            pltpu.VMEM((bq, 1), jnp.float32),        # running sum
            pltpu.VMEM((bq, d), jnp.float32),        # accumulator
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Paged KV gather (scalar-prefetch block-table indexed copy)
# ---------------------------------------------------------------------------

def _gather_kernel(tbl_ref, pool_ref, o_ref):
    del tbl_ref  # consumed by the index maps
    o_ref[0] = pool_ref[0]


def paged_gather(pool: jax.Array, tables: jax.Array, *,
                 interpret: bool = False) -> jax.Array:
    """Block-table gather: pool [N, P, ...] + tables [B, M] (entries already
    clipped into [0, N-1] by the ops wrapper) -> dense view [B, M*P, ...].

    The table rides scalar prefetch (PrefetchScalarGridSpec) so each grid
    step's input BlockSpec picks pool block `tables[b, m]` directly -- the
    copy itself is a straight VMEM move, one (P, F) tile per page.
    """
    n, p = pool.shape[0], pool.shape[1]
    b, m = tables.shape
    trailing = pool.shape[2:]
    f = 1
    for dim in trailing:
        f *= dim
    pool_f = pool.reshape(n, p, f)
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, m),
            in_specs=[
                pl.BlockSpec((1, p, f), lambda bi, mi, tbl: (tbl[bi, mi],
                                                             0, 0)),
            ],
            out_specs=pl.BlockSpec((1, p, f), lambda bi, mi, tbl: (
                bi * m + mi, 0, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((b * m, p, f), pool.dtype),
        interpret=interpret,
    )(tables, pool_f)
    return out.reshape((b, m * p) + trailing)
