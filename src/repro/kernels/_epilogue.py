"""In-register fused-epilogue chain: the math every PE tail shares.

`passes.fuse_epilogues` folds {residual add, avg/global/max pool tail,
activation, requant} chains into the producing Conv/DWC launch.  This module
is the single definition of that chain's VALUE semantics, applied to the
PE's post-activation output while it is still in registers/VMEM:

  * the Pallas kernels (conv_pe, dwc_pe, low_channel) call `fused_chain`
    inside their epilogue, so the whole chain is one launch with no
    intermediate tensor materialized;
  * the ref / baseline backends call it from kernels/ops.py on the full
    array -- XLA fuses it into the surrounding computation, and it serves as
    the bit-exact oracle for the Pallas path.

Static programs (mid_scale given) quantize-dequantize IN-REGISTER at
exactly the interior edge scales the unfused program materialized tensors
at, so fused int8 execution is bit-identical to running the ops separately:
the value stream is unchanged, only the memory traffic disappears.  Dynamic
programs (mid_scale None) run the chain in f32.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.ref import act_fn


def _qdq(x: jnp.ndarray, scale: float) -> jnp.ndarray:
    """In-register requant to an interior edge scale: integer-valued f32."""
    return jnp.clip(jnp.round(x / scale), -127.0, 127.0)


def _taps(x, k: int, stride: int):
    """VALID pooling windows as strided tap slices over the trailing
    (H, W, C) dims -- the same unrolled-tap walk the PE kernels use."""
    h, w = x.shape[-3], x.shape[-2]
    ho = (h - k) // stride + 1
    wo = (w - k) // stride + 1
    for kh in range(k):
        for kw in range(k):
            yield x[..., kh:kh + (ho - 1) * stride + 1:stride,
                    kw:kw + (wo - 1) * stride + 1:stride, :]


def fused_chain(x: jnp.ndarray, *,
                mid_scale: Optional[float] = None,
                residual: Optional[jnp.ndarray] = None,
                res_scale: float = 1.0,
                add_act: str = "none",
                add_scale: Optional[float] = None,
                pool: str = "none",
                pool_kernel: int = 0,
                pool_stride: int = 0,
                out_scale: Optional[float] = None) -> jnp.ndarray:
    """Apply a fused Epilogue chain to the PE output.

    x: f32 [..., H, W, C], the conv/dwc result AFTER its own bias +
    activation, BEFORE any requant.  residual: raw operand values (int8 or
    f32), same shape.  Scales are compile-time python floats (static chain)
    or None (dynamic f32 chain).  Returns int8 for a static chain ending in
    a requant (or the scale-preserving max tail), f32 otherwise.
    """
    static = mid_scale is not None
    if static:
        x = _qdq(x, mid_scale)                 # the absorbed conv edge
    cur = mid_scale
    if residual is not None:
        r = residual.astype(jnp.float32) * res_scale
        x = (x * mid_scale + r) if static else (x + r)
        x = act_fn(add_act)(x)
        if static:
            cur = add_scale if pool != "none" else out_scale
            if cur is not None:
                x = _qdq(x, cur)               # the absorbed add edge
    if pool == "none":
        if static and cur is not None:
            return x.astype(jnp.int8)
        return x
    if pool == "max":
        # Order-preserving on the quantized values: scale passes through,
        # exactly like the standalone max pool's scale-preserving rule.
        y = None
        for t in _taps(x, pool_kernel, pool_stride):
            y = t if y is None else jnp.maximum(y, t)
        return y.astype(jnp.int8) if static else y
    if pool == "global":
        if static:
            # Sum in int32 like every engine accumulator, then one fused
            # scale + requant -- the executor's standalone GAP, in-register.
            px = x.shape[-3] * x.shape[-2]
            acc = jnp.sum(x.astype(jnp.int32), axis=(-3, -2))
            y = acc.astype(jnp.float32) * (cur / px)
        else:
            y = jnp.mean(x, axis=(-3, -2))
    else:                                       # avg
        if static:
            acc = None
            for t in _taps(x.astype(jnp.int32), pool_kernel, pool_stride):
                acc = t if acc is None else acc + t
            y = acc.astype(jnp.float32) * (cur / pool_kernel ** 2)
        else:
            acc = None
            for t in _taps(x, pool_kernel, pool_stride):
                acc = t if acc is None else acc + t
            y = acc / pool_kernel ** 2
    if static and out_scale is not None:
        return jnp.clip(jnp.round(y / out_scale), -127, 127).astype(jnp.int8)
    return y


def chain_out_dtype(mid_scale, pool: str, out_scale, out_dtype):
    """The dtype `fused_chain` emits (for kernel out_shape declarations)."""
    if mid_scale is not None and (out_scale is not None or pool == "max"):
        return jnp.int8
    return out_dtype


def pooled_hw(ho: int, wo: int, pool: str, k: int, stride: int):
    """Output spatial dims after the chain's pool stage."""
    if pool == "none":
        return ho, wo
    if pool == "global":
        return 1, 1
    return (ho - k) // stride + 1, (wo - k) // stride + 1
