"""Pure-jnp reference oracles for every DPUV4E kernel.

These define the semantics the Pallas kernels must match bit-for-bit (int
paths) or to float tolerance (epilogue paths).  They are also the "ref"
backend used for CPU execution and for the dry-run lowering (XLA-TPU fuses
the same epilogues the Pallas kernels fuse by hand).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Activations (the NL core's menu, Section IV-B2)
# ---------------------------------------------------------------------------

def act_fn(name: str):
    return {
        "none": lambda x: x,
        "relu": jax.nn.relu,
        "relu2": lambda x: jnp.square(jax.nn.relu(x)),
        "relu6": lambda x: jnp.clip(x, 0.0, 6.0),
        "silu": jax.nn.silu,
        "gelu": lambda x: jax.nn.gelu(x, approximate=True),
        "hardswish": jax.nn.hard_swish,
    }[name]


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# C2: Conv PE -- int8 GEMM with cascade accumulation + fused NL epilogue
# ---------------------------------------------------------------------------

def matmul_int8_fused(a_q: jax.Array, b_q: jax.Array,
                      a_scale: jax.Array, w_scale: jax.Array,
                      bias: Optional[jax.Array] = None,
                      act: str = "none",
                      out_scale: Optional[jax.Array] = None,
                      out_dtype=jnp.float32) -> jax.Array:
    """out = requant(act(dequant(a_q @ b_q) + bias)).

    a_q:      int8 [M, K];     a_scale: f32 [M, 1] (per-token) or scalar
    b_q:      int8 [K, N];     w_scale: f32 [1, N] (per-channel) or scalar
    bias:     f32 [N] or None
    out_scale: f32 scalar -> int8 output, None -> float output.
    """
    acc = jnp.dot(a_q.astype(jnp.int32), b_q.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    x = acc.astype(jnp.float32) * a_scale * w_scale
    if bias is not None:
        x = x + bias
    x = act_fn(act)(x)
    if out_scale is not None:
        q = jnp.clip(jnp.round(x / out_scale), -127, 127)
        return q.astype(jnp.int8)
    return x.astype(out_dtype)


def matmul_int8_unfused(a_q, b_q, a_scale, w_scale, bias=None, act="none",
                        out_scale=None, out_dtype=jnp.float32):
    """XVDPU-analog baseline: the int32 partial sums round-trip to HBM and the
    epilogue runs as separate (PL-side, in the paper) ops."""
    acc = jnp.dot(a_q.astype(jnp.int32), b_q.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    acc = jax.lax.optimization_barrier(acc)     # forbid XLA epilogue fusion
    x = acc.astype(jnp.float32) * a_scale * w_scale
    if bias is not None:
        x = jax.lax.optimization_barrier(x + bias)
    x = act_fn(act)(x)
    if out_scale is not None:
        q = jnp.clip(jnp.round(x / out_scale), -127, 127)
        return q.astype(jnp.int8)
    return x.astype(out_dtype)


def int4_group_dot(a_q: jax.Array, codes: jax.Array,
                   w_scale: jax.Array, w_zero: jax.Array) -> jax.Array:
    """The int4 weight-only MAC the Conv PE runs in-register.

    a_q: int8/int32 [M, K]; codes: int32 [K, N] in [0, 15];
    w_scale/w_zero: [G, N] per-group (K = G * gs).  Partial sums stay exact
    int32 per group, then one f32 scale/zero combine per group:

        out[m, n] = sum_g scale[g, n] * (a[m, g*gs:..] . codes[g*gs:.., n])
                  + sum_g zero[g, n]  * (sum_k a[m, g*gs + k])

    This is the single definition of the w4 GEMM value stream -- the Pallas
    kernel applies the identical expression per block (column/row blocking
    never reorders a group reduction), so ref and pallas agree bitwise.
    """
    m, k = a_q.shape
    g, n = w_scale.shape
    gs = k // g
    ag = a_q.astype(jnp.int32).reshape(m, g, gs)
    cg = codes.astype(jnp.int32).reshape(g, gs, n)
    part = jnp.einsum("mgk,gkn->mgn", ag, cg,
                      preferred_element_type=jnp.int32)
    acc = jnp.sum(part.astype(jnp.float32)
                  * w_scale.astype(jnp.float32)[None], axis=1)
    asum = jnp.sum(ag, axis=-1).astype(jnp.float32)            # [M, G]
    return acc + jnp.dot(asum, w_zero.astype(jnp.float32))


def matmul_int4_fused(a_q: jax.Array, b_packed: jax.Array,
                      a_scale: jax.Array, w_scale: jax.Array,
                      w_zero: jax.Array,
                      bias: Optional[jax.Array] = None,
                      act: str = "none",
                      out_scale: Optional[jax.Array] = None,
                      out_dtype=jnp.float32) -> jax.Array:
    """Int4 weight-only GEMM oracle: unpack -> group dot -> NL epilogue.

    a_q: int8 [M, K] with a_scale f32 [M, 1] (per-token) or scalar;
    b_packed: uint8 [K//2, N] nibble pairs with w_scale/w_zero [G, N].
    Same epilogue contract as matmul_int8_fused.
    """
    from repro.core.quant import unpack_int4

    codes = unpack_int4(b_packed)
    x = int4_group_dot(a_q, codes, w_scale, w_zero) * a_scale
    if bias is not None:
        x = x + bias
    x = act_fn(act)(x)
    if out_scale is not None:
        q = jnp.clip(jnp.round(x / out_scale), -127, 127)
        return q.astype(jnp.int8)
    return x.astype(out_dtype)


# ---------------------------------------------------------------------------
# C4: DWC PE -- depthwise convolution, NHWC
# ---------------------------------------------------------------------------

def dwc2d(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
          stride: int = 1, act: str = "none",
          a_scale: Optional[jax.Array] = None,
          w_scale: Optional[jax.Array] = None,
          out_scale: Optional[jax.Array] = None,
          out_dtype=jnp.float32) -> jax.Array:
    """Depthwise conv on a pre-padded input (VALID semantics).

    x: [N, H, W, C] (int8 or float), w: [k, k, C], bias: [C].
    Quantized mode when a_scale/w_scale given (int8 x int8 -> int32).
    """
    k = w.shape[0]
    n, h, wd, c = x.shape
    ho = (h - k) // stride + 1
    wo = (wd - k) // stride + 1
    quant = a_scale is not None
    acc_dtype = jnp.int32 if quant else jnp.float32
    acc = jnp.zeros((n, ho, wo, c), acc_dtype)
    for kh in range(k):
        for kw in range(k):
            xs = jax.lax.slice(
                x, (0, kh, kw, 0),
                (n, kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1, c),
                (1, stride, stride, 1))
            acc = acc + xs.astype(acc_dtype) * w[kh, kw, :].astype(acc_dtype)
    if quant:
        xf = acc.astype(jnp.float32) * a_scale * w_scale
    else:
        xf = acc
    if bias is not None:
        xf = xf + bias
    xf = act_fn(act)(xf)
    if out_scale is not None:
        q = jnp.clip(jnp.round(xf / out_scale), -127, 127)
        return q.astype(jnp.int8)
    return xf.astype(out_dtype)


def dwc1d_causal(x: jax.Array, w: jax.Array,
                 bias: Optional[jax.Array] = None,
                 act: str = "none", out_dtype=jnp.float32) -> jax.Array:
    """Causal depthwise temporal conv (mamba / RG-LRU frontend).

    x: [B, L, C] float, w: [k, C], bias: [C].
    """
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    acc = jnp.zeros_like(x, dtype=jnp.float32)
    l = x.shape[1]
    for i in range(k):
        acc = acc + xp[:, i:i + l, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    if bias is not None:
        acc = acc + bias
    return act_fn(act)(acc).astype(out_dtype)


# ---------------------------------------------------------------------------
# C5: Low-Channel Conv Unit -- first-layer conv (small IC)
# ---------------------------------------------------------------------------

def low_channel_conv(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
                     stride: int, act: str = "none",
                     a_scale: Optional[jax.Array] = None,
                     w_scale: Optional[jax.Array] = None,
                     out_scale: Optional[jax.Array] = None,
                     out_dtype=jnp.float32) -> jax.Array:
    """Standard conv on pre-padded input (VALID), small IC.

    x: [N, H, W, IC], w: [k, k, IC, OC], bias: [OC].
    """
    k = w.shape[0]
    n, h, wd, ic = x.shape
    oc = w.shape[-1]
    ho = (h - k) // stride + 1
    wo = (wd - k) // stride + 1
    quant = a_scale is not None
    acc_dtype = jnp.int32 if quant else jnp.float32
    acc = jnp.zeros((n, ho, wo, oc), acc_dtype)
    for kh in range(k):
        for kw in range(k):
            xs = jax.lax.slice(
                x, (0, kh, kw, 0),
                (n, kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1, ic),
                (1, stride, stride, 1))
            tap = jnp.einsum("nhwc,co->nhwo", xs.astype(acc_dtype),
                             w[kh, kw].astype(acc_dtype))
            acc = acc + tap
    xf = acc.astype(jnp.float32)
    if quant:
        xf = xf * a_scale * w_scale
    if bias is not None:
        xf = xf + bias
    xf = act_fn(act)(xf)
    if out_scale is not None:
        q = jnp.clip(jnp.round(xf / out_scale), -127, 127)
        return q.astype(jnp.int8)
    return xf.astype(out_dtype)


# ---------------------------------------------------------------------------
# C6: MISC core -- fused elementwise / pooling
# ---------------------------------------------------------------------------

def misc_add(a: jax.Array, b: jax.Array,
             sa: float = 1.0, sb: float = 1.0, act: str = "none",
             out_scale: Optional[jax.Array] = None,
             out_dtype=jnp.float32) -> jax.Array:
    x = a.astype(jnp.float32) * sa + b.astype(jnp.float32) * sb
    x = act_fn(act)(x)
    if out_scale is not None:
        q = jnp.clip(jnp.round(x / out_scale), -127, 127)
        return q.astype(jnp.int8)
    return x.astype(out_dtype)


def avgpool2d(x: jax.Array, window: int, stride: int,
              out_dtype=jnp.float32) -> jax.Array:
    """[N, H, W, C] average pool, VALID."""
    s = jax.lax.reduce_window(
        x.astype(jnp.float32), 0.0, jax.lax.add,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")
    return (s / (window * window)).astype(out_dtype)


def maxpool2d(x: jax.Array, window: int, stride: int) -> jax.Array:
    if jnp.issubdtype(x.dtype, jnp.floating):
        init = -jnp.inf
    else:  # int8 path: the MISC comparator works on quantized values directly
        init = jnp.array(jnp.iinfo(x.dtype).min, x.dtype)
    return jax.lax.reduce_window(
        x, init, jax.lax.max,
        (1, window, window, 1), (1, stride, stride, 1), "VALID")


def global_avgpool(x: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    return jnp.mean(x.astype(jnp.float32), axis=(1, 2)).astype(out_dtype)


# ---------------------------------------------------------------------------
# Attention oracle (for the flash kernel / flash-decode combine)
# ---------------------------------------------------------------------------

def attention(q: jax.Array, k: jax.Array, v: jax.Array,
              causal: bool = True, window: int = 0,
              logit_softcap: float = 0.0, scale: Optional[float] = None) -> jax.Array:
    """q: [B, Hq, Lq, D], k/v: [B, Hkv, Lk, D] (GQA by head repetition)."""
    b, hq, lq, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k).astype(jnp.float32) * scale
    if logit_softcap > 0:
        logits = softcap(logits, logit_softcap)
    lk = k.shape[2]
    qpos = jnp.arange(lq)[:, None] + (lk - lq)
    kpos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window > 0:
        mask = mask & (kpos > qpos - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)


# ---------------------------------------------------------------------------
# Paged KV gather (block-table indexed cache -> slot-ordered dense view)
# ---------------------------------------------------------------------------

def paged_gather(pool: jax.Array, tables: jax.Array) -> jax.Array:
    """Gather block-paged cache state into a slot-ordered dense view.

    pool:   [N, P, ...] -- N blocks of P positions (K, V, or scale pools).
    tables: [B, M] int32 -- block id of slot b's m-th page (values are
            clipped into [0, N-1], so sentinel/unallocated entries read
            SOME finite block whose data the decode mask discards).
    Returns [B, M*P, ...]: the exact values the dense cache would hold at
    every in-length position -- a pure copy, the paged/dense bit-identity
    anchor the Pallas kernel is checked against.
    """
    n, p = pool.shape[0], pool.shape[1]
    b, m = tables.shape
    blk = jnp.clip(tables, 0, n - 1)
    flat = (blk[..., None] * p + jnp.arange(p)[None, None, :]
            ).reshape(b, m * p)
    return pool.reshape((n * p,) + pool.shape[2:])[flat]
