"""Pallas API compatibility across jax versions.

jax >= 0.5 exposes `pltpu.CompilerParams`; 0.4.x calls the same dataclass
`pltpu.TPUCompilerParams`.  Every kernel builds its compiler params through
this helper so the repo runs on either.
"""
from __future__ import annotations

import jax.experimental.pallas.tpu as pltpu

_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def compiler_params(**kwargs):
    return _CLS(**kwargs)
