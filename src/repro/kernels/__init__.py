"""DPUV4E engine kernels: Pallas TPU implementations + jnp oracles.

conv_pe     -- C2/C3: int8 GEMM, cascade K-accumulation, fused NL epilogue
dwc_pe      -- C4:    depthwise conv engine (2-D and causal 1-D)
low_channel -- C5:    first-layer small-IC conv (VMEM im2col fusion)
misc_pe     -- C6:    fused elementwise / pooling
flash_attn  -- beyond-paper: blocked attention kernel
ops         -- public wrappers (backend select, padding, DSE blocks)
ref         -- pure-jnp oracles
"""
