"""Low-Channel Conv Unit: the first-layer specialization.

Paper (Section V-B): the graph-level Conv PE has 64(IC) x 128(OC) parallelism,
so a ResNet50 stage-0 conv (7x7, IC=3, OC=64) runs at 13.1% utilization; a
dedicated PL unit with 4(H) x 21(IC) x 32(OC) parallelism (672 DSP58s) handles
it concurrently, buying +1.14x throughput / -7.5% latency.

TPU adaptation: the MXU has the same pathology (IC=3 against a 128-deep
contraction).  The fix is the classic TPU one, and it is *the same idea the
paper's 21-wide IC datapath exploits*: fold the kernel window into the
contraction so the effective IC becomes IC*K*K (3*49 = 147 >= 128).  We fuse
the im2col into the kernel: the input tile is loaded into VMEM ONCE and
re-read for all K*K taps (each tap a [pixels, IC] x [IC, OC] MXU matmul into a
revolving accumulator), so HBM never sees the 49x-inflated patch tensor.

Grid: (N,) -- first layers are tiny (a 224x224x4 int8 image is 200 KB); one
batch element per cell with the full spatial extent resident.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import act_fn
from repro.kernels import _epilogue
from repro.kernels._pallas_compat import compiler_params


def _kernel(x_ref, w_ref, bias_ref, scale_ref, o_ref, *,
            k: int, stride: int, ho: int, wo: int, act: str,
            quant: bool, out_scale: Optional[float],
            mid_scale: Optional[float], pool: str, pool_kernel: int,
            pool_stride: int):
    x = x_ref[0]                        # [Hp, Wp, IC]
    ic = x.shape[-1]
    oc = o_ref.shape[-1]
    acc_dtype = jnp.int32 if quant else jnp.float32
    acc = jnp.zeros((ho * wo, oc), acc_dtype)
    for kh in range(k):                 # VMEM im2col: x re-read per tap
        for kw in range(k):
            xs = jax.lax.slice(
                x, (kh, kw, 0),
                (kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1, ic),
                (stride, stride, 1)).reshape(ho * wo, ic)
            acc = acc + jnp.dot(xs.astype(acc_dtype),
                                w_ref[kh, kw].astype(acc_dtype),
                                preferred_element_type=acc_dtype)
    xf = acc.astype(jnp.float32)
    if quant:
        xf = xf * scale_ref[0]             # [OC] per-channel dequant
    xf = xf + bias_ref[0]
    xf = act_fn(act)(xf)
    if pool != "none":
        # fused pool tail (e.g. the stem -> max-pool chain): the pre-pool
        # stem feature map never leaves the unit
        y = _epilogue.fused_chain(
            xf.reshape(ho, wo, oc), mid_scale=mid_scale, pool=pool,
            pool_kernel=pool_kernel, pool_stride=pool_stride,
            out_scale=out_scale)
        if pool == "global":
            y = y.reshape(1, 1, oc)
        o_ref[0] = y.astype(o_ref.dtype)
        return
    if out_scale is not None:              # fused requant (NL epilogue)
        xf = jnp.clip(jnp.round(xf / out_scale), -127, 127)
    o_ref[0] = xf.reshape(ho, wo, oc).astype(o_ref.dtype)


def low_channel_conv(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
                     stride: int, act: str = "none",
                     a_scale: Optional[float] = None,
                     w_scale: Optional[float] = None,
                     out_scale: Optional[float] = None,
                     out_dtype=jnp.float32, *,
                     mid_scale: Optional[float] = None,
                     pool: str = "none", pool_kernel: int = 0,
                     pool_stride: int = 0,
                     interpret: bool = False) -> jax.Array:
    """First-layer conv on pre-padded input (VALID).

    x: [N, Hp, Wp, IC] (IC small), w: [k, k, IC, OC], bias: [OC].
    Quantized path fuses the activation scale with the weight scale
    (per-tensor scalar or per-output-channel [OC]); a_scale / w_scale may
    be Python floats or (traced) arrays.  out_scale requants to int8 in
    the epilogue and must be static.

    pool ("avg" | "global" | "max") fuses an absorbed pool tail into the
    epilogue (mid_scale: the static pre-pool edge scale); the output is
    then [N, PHo, PWo, OC].
    """
    n, hp, wp, ic = x.shape
    k, _, _, oc = w.shape
    ho = (hp - k) // stride + 1
    wo = (wp - k) // stride + 1
    quant = a_scale is not None
    scale = (jnp.asarray(a_scale, jnp.float32)
             * jnp.asarray(w_scale, jnp.float32) if quant
             else jnp.ones((), jnp.float32))
    scale_arr = jnp.broadcast_to(scale.reshape(-1), (oc,)).reshape(1, oc)
    bias_arr = (bias.astype(jnp.float32).reshape(1, oc) if bias is not None
                else jnp.zeros((1, oc), jnp.float32))
    pho, pwo = _epilogue.pooled_hw(ho, wo, pool, pool_kernel, pool_stride)
    if pool != "none":
        odt = _epilogue.chain_out_dtype(mid_scale, pool, out_scale, out_dtype)
    else:
        odt = jnp.int8 if out_scale is not None else out_dtype
    return pl.pallas_call(
        functools.partial(_kernel, k=k, stride=stride, ho=ho, wo=wo, act=act,
                          quant=quant, out_scale=out_scale,
                          mid_scale=mid_scale, pool=pool,
                          pool_kernel=pool_kernel, pool_stride=pool_stride),
        grid=(n,),
        in_specs=[
            pl.BlockSpec((1, hp, wp, ic), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((k, k, ic, oc), lambda i: (0, 0, 0, 0)),
            pl.BlockSpec((1, oc), lambda i: (0, 0)),
            pl.BlockSpec((1, oc), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, pho, pwo, oc), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, pho, pwo, oc), odt),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w, bias_arr, scale_arr)
