"""Public kernel wrappers: backend selection, padding, DSE-chosen blocks.

Every op has three backends:
  * "ref"     -- pure-jnp oracle (kernels/ref.py).  CPU execution and the
                 dry-run lowering use this path.
  * "pallas"  -- the Pallas TPU kernel (interpret=True on this CPU container).
  * baseline  -- the XVDPU-analog unfused path (ref.matmul_int8_unfused).

Wrappers own all shape legalization: flattening leading dims, padding M/N/K
to block multiples (the paper's bank-alignment / zero-padding steps), and
channel padding to the 128-lane width for the DWC engine.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dse
from repro.core.config import EngineConfig
from repro.core.quant import (Q4Tensor, QTensor, quantize_act_dynamic,
                              quantize_static)
from repro.kernels import _epilogue, conv_pe, dwc_pe, low_channel, misc_pe, ref

# Quant modes with int8 activations on the Conv PE fabric (w4a8 packs LM
# projection *weights* to int4; everything else runs exactly like w8a8).
_INT8_ACTS = ("w8a8", "w4a8")


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _chain_kwargs(ep, static: bool, out_scale):
    """Epilogue spec -> kernels/_epilogue.fused_chain kwargs.  Static
    programs carry the interior requant points; dynamic chains run f32."""
    return dict(
        mid_scale=ep.mid_scale if static and ep.mid_scale else None,
        add_act=ep.add_act,
        add_scale=ep.add_scale if static and ep.add_scale else None,
        pool=ep.pool, pool_kernel=ep.pool_kernel, pool_stride=ep.pool_stride,
        out_scale=out_scale if static else None)


def _pad2d(x: jax.Array, m: int, n: int) -> jax.Array:
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def pick_blocks(m: int, n: int, k: int, in_bytes: int,
                cfg: EngineConfig):
    """Block shapes: explicit config overrides, else the DSE solver."""
    if cfg.block_m and cfg.block_n and cfg.cascade_bk:
        return cfg.block_m, cfg.block_n, cfg.cascade_bk
    t = dse.solve_conv_blocks(m, n, k, in_dtype_bytes=in_bytes)
    bm = min(t.bm, _round_up(m, 128))
    bn = min(t.bn, _round_up(n, 128))
    bk = min(t.bk, _round_up(k, 128))
    return bm, bn, bk


# ---------------------------------------------------------------------------
# Conv PE: quantized linear (the LM projection / 1x1-conv path)
# ---------------------------------------------------------------------------

def linear_int8(x, w: QTensor, bias: Optional[jax.Array],
                act: str, cfg: EngineConfig,
                out_dtype=jnp.float32,
                out_scale=None,
                residual: Optional[jax.Array] = None,
                res_scale: float = 1.0,
                mid_scale: Optional[float] = None,
                add_act: str = "none") -> jax.Array:
    """x: float [..., K] (dynamic per-token act quant) OR QTensor with a
    static pre-calibrated per-tensor scale (the compiled engine-program
    path); w: QTensor(q=[K, N] int8, scale=[1, N]).

    out_scale: static requant scale -> int8 output via the NL epilogue
    (activations stay int8 engine-to-engine); a per-output-channel tuple
    requants each channel at its own scale (a per-channel edge feeding the
    channelwise DWC engine); None -> float output.

    residual [..., N] streams a fused-epilogue second operand into the
    Pallas kernel (the absorbed residual add; conv2d_pe's epilogue path);
    only the pallas backend takes it -- ref/baseline compose the chain in
    the wrapper instead.
    """
    static = isinstance(x, QTensor)
    xv = x.q if static else x
    lead = xv.shape[:-1]
    kdim = xv.shape[-1]
    n = w.q.shape[-1]
    if out_scale is not None and not isinstance(out_scale, (int, float)):
        out_scale = jnp.asarray(out_scale, jnp.float32).reshape(1, n)
    m = 1
    for d in lead:
        m *= d
    x2 = xv.reshape(m, kdim)
    if static:
        xq = QTensor(x2, jnp.full((m, 1), float(x.scale), jnp.float32))
    else:
        xq = quantize_act_dynamic(x2, per_token=True)      # a_scale [M, 1]
    w_scale = w.scale.reshape(1, n)

    if cfg.baseline:
        assert residual is None, "fused residual composes in the wrapper"
        out = ref.matmul_int8_unfused(xq.q, w.q, xq.scale, w_scale, bias, act,
                                      out_scale=out_scale, out_dtype=out_dtype)
    elif cfg.backend == "pallas":
        bm, bn, bk = pick_blocks(m, n, kdim, 1, cfg)
        mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(kdim, bk)
        aq = _pad2d(xq.q, mp, kp)
        bq = _pad2d(w.q, kp, np_)
        asc = jnp.pad(xq.scale, ((0, mp - m), (0, 0)))
        wsc = jnp.pad(w_scale, ((0, 0), (0, np_ - n)))
        b = (jnp.pad(bias.astype(jnp.float32), (0, np_ - n))
             if bias is not None else None)
        osc = out_scale
        if out_scale is not None and not isinstance(out_scale, (int, float)):
            # per-channel requant vector: pad with 1s alongside N
            osc = jnp.pad(jnp.asarray(out_scale, jnp.float32).reshape(1, n),
                          ((0, 0), (0, np_ - n)), constant_values=1.0)
        r = (_pad2d(residual.reshape(m, n), mp, np_)
             if residual is not None else None)
        out = conv_pe.matmul_int8_fused(
            aq, bq, asc, wsc, b, act, out_scale=osc, out_dtype=out_dtype,
            residual=r, res_scale=res_scale, mid_scale=mid_scale,
            add_act=add_act, bm=bm, bn=bn, bk=bk,
            interpret=cfg.interpret)[:m, :n]
    else:
        assert residual is None, "fused residual composes in the wrapper"
        out = ref.matmul_int8_fused(xq.q, w.q, xq.scale, w_scale, bias, act,
                                    out_scale=out_scale, out_dtype=out_dtype)
    return out.reshape(*lead, n)


def linear_w4(x, w: Q4Tensor, bias: Optional[jax.Array],
              act: str, cfg: EngineConfig,
              out_dtype=jnp.float32,
              out_scale=None,
              residual: Optional[jax.Array] = None,
              res_scale: float = 1.0,
              mid_scale: Optional[float] = None,
              add_act: str = "none") -> jax.Array:
    """Int4 weight-only GEMM over int8 activations (quant='w4a8').

    x: float [..., K] (dynamic per-token act quant) OR QTensor with a static
    pre-calibrated per-tensor scale; w: Q4Tensor (packed [K//2, N] nibble
    pairs + per-group f16 scale/zero).  The Pallas kernel unpacks and
    dequantizes the weight block in-register (XEGEMM_INT4 idiom); K is never
    padded -- the kernel runs whole-K blocks so per-group partial sums stay
    exact and bitwise-match the ref oracle.  Epilogue contract (out_scale /
    residual / mid_scale / add_act) matches linear_int8.
    """
    static = isinstance(x, QTensor)
    xv = x.q if static else x
    lead = xv.shape[:-1]
    kdim = xv.shape[-1]
    n = w.packed.shape[-1]
    if out_scale is not None and not isinstance(out_scale, (int, float)):
        out_scale = jnp.asarray(out_scale, jnp.float32).reshape(1, n)
    m = 1
    for d in lead:
        m *= d
    x2 = xv.reshape(m, kdim)
    if static:
        xq = QTensor(x2, jnp.full((m, 1), float(x.scale), jnp.float32))
    else:
        xq = quantize_act_dynamic(x2, per_token=True)      # a_scale [M, 1]

    if cfg.backend == "pallas" and not cfg.baseline:
        bm, bn, _ = pick_blocks(m, n, kdim, 1, cfg)
        mp, np_ = _round_up(m, bm), _round_up(n, bn)
        aq = _pad2d(xq.q, mp, kdim)                        # pad M only
        asc = jnp.pad(xq.scale, ((0, mp - m), (0, 0)))
        # N padding: packed columns pad with zero codes and zero
        # scale/zero, so padded outputs are exactly 0 and slice off.
        bq = _pad2d(w.packed, kdim // 2, np_)
        wsc = jnp.pad(w.scale, ((0, 0), (0, np_ - n)))
        wz = jnp.pad(w.zero, ((0, 0), (0, np_ - n)))
        b = (jnp.pad(bias.astype(jnp.float32), (0, np_ - n))
             if bias is not None else None)
        osc = out_scale
        if out_scale is not None and not isinstance(out_scale, (int, float)):
            osc = jnp.pad(jnp.asarray(out_scale, jnp.float32).reshape(1, n),
                          ((0, 0), (0, np_ - n)), constant_values=1.0)
        r = (_pad2d(residual.reshape(m, n), mp, np_)
             if residual is not None else None)
        out = conv_pe.matmul_int4_fused(
            aq, bq, asc, wsc, wz, b, act, out_scale=osc, out_dtype=out_dtype,
            residual=r, res_scale=res_scale, mid_scale=mid_scale,
            add_act=add_act, bm=bm, bn=bn,
            interpret=cfg.interpret)[:m, :n]
    else:
        assert residual is None, "fused residual composes in the wrapper"
        out = ref.matmul_int4_fused(xq.q, w.packed, xq.scale, w.scale, w.zero,
                                    bias, act, out_scale=out_scale,
                                    out_dtype=out_dtype)
    return out.reshape(*lead, n)


def linear_w8(x: jax.Array, w: QTensor, bias: Optional[jax.Array],
              act: str, cfg: EngineConfig, out_dtype=jnp.float32) -> jax.Array:
    """Weight-only int8: dequantize weights, bf16 MAC (memory-bound decode)."""
    wf = w.dequant(x.dtype)
    out = jnp.dot(x, wf)
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return ref.act_fn(act)(out).astype(out_dtype)


def linear_f(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
             act: str, cfg: EngineConfig, out_dtype=None) -> jax.Array:
    """Float path (training)."""
    out_dtype = out_dtype or x.dtype
    out = jnp.dot(x, w.astype(x.dtype))
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return ref.act_fn(act)(out).astype(out_dtype)


def linear(x, w, bias, act: str, cfg: EngineConfig,
           out_dtype=None, out_scale: Optional[float] = None,
           residual: Optional[jax.Array] = None, res_scale: float = 1.0,
           mid_scale: Optional[float] = None,
           add_act: str = "none") -> jax.Array:
    """Dispatch on quant mode and weight container type.

    x may be a QTensor (pre-quantized int8 activations with a static scale);
    that path requires int8-act quant (w8a8/w4a8) + quantized weights.
    out_scale (static) requests int8 output via the fused requant epilogue.
    residual/res_scale/mid_scale/add_act thread a fused residual epilogue
    into the int8/int4 kernel (the pallas paths only).
    """
    if isinstance(w, Q4Tensor):
        if cfg.quant != "w4a8":
            raise ValueError(
                "Q4Tensor weights require quant='w4a8' (got %r)" % cfg.quant)
        return linear_w4(x, w, bias, act, cfg,
                         out_dtype=out_dtype or jnp.float32,
                         out_scale=out_scale, residual=residual,
                         res_scale=res_scale, mid_scale=mid_scale,
                         add_act=add_act)
    if isinstance(w, QTensor) and cfg.quant in _INT8_ACTS:
        return linear_int8(x, w, bias, act, cfg,
                           out_dtype=out_dtype or jnp.float32,
                           out_scale=out_scale, residual=residual,
                           res_scale=res_scale, mid_scale=mid_scale,
                           add_act=add_act)
    if residual is not None:
        raise ValueError("fused residual epilogues require quant='w8a8'/"
                         "'w4a8' with quantized weights")
    if isinstance(x, QTensor) or out_scale is not None:
        raise ValueError(
            "static int8 activations / out_scale require quant='w8a8'/'w4a8' "
            "with quantized weights (got quant=%r, w=%s)"
            % (cfg.quant, type(w).__name__))
    if isinstance(w, QTensor):
        return linear_w8(x, w, bias, act, cfg,
                         out_dtype=out_dtype or x.dtype)
    return linear_f(x, w, bias, act, cfg, out_dtype=out_dtype)


def linear_group(x, ws, bs, acts, cfg: EngineConfig,
                 out_dtype=None):
    """Fused multi-output projection group (Q/K/V, gate/up): one shared
    input, member outputs returned as a tuple.

    On the pallas int8/int4 paths the member weights concatenate along N
    into ONE kernel launch (the XEGEMM ``hgemm_qkv_wint4(q, out0, out1,
    out2, ...)`` idiom): the activation row is quantized and read once and
    every member's columns MAC in the same grid.  Column blocks never mix
    members' reductions, so slicing the fused f32 output is bitwise
    identical to member-wise launches.  Float / ref / baseline paths compose
    member-wise -- bit-identical to the unfused graph by construction.
    """
    ns = [w.shape[-1] for w in ws]
    kinds = {type(w) for w in ws}
    pallas = cfg.backend == "pallas" and not cfg.baseline
    fused = None
    if pallas and kinds == {QTensor} and cfg.quant in _INT8_ACTS:
        fused = QTensor(
            jnp.concatenate([w.q for w in ws], axis=1),
            jnp.concatenate([w.scale.reshape(1, -1) for w in ws], axis=1))
    elif pallas and kinds == {Q4Tensor} and cfg.quant == "w4a8":
        # Members share K (one input) and the snapped group size, so the
        # per-group scale/zero tables concatenate along N too.
        fused = Q4Tensor(
            jnp.concatenate([w.packed for w in ws], axis=1),
            jnp.concatenate([w.scale for w in ws], axis=1),
            jnp.concatenate([w.zero for w in ws], axis=1))
    if fused is None:
        return tuple(linear(x, w, b, a, cfg, out_dtype=out_dtype)
                     for w, b, a in zip(ws, bs, acts))
    bias = None
    if any(b is not None for b in bs):
        bias = jnp.concatenate(
            [b.astype(jnp.float32) if b is not None
             else jnp.zeros((nn,), jnp.float32) for b, nn in zip(bs, ns)])
    out = linear(x, fused, bias, "none", cfg, out_dtype=jnp.float32)
    outs, off = [], 0
    for nn, a in zip(ns, acts):
        y = out[..., off:off + nn]
        if a != "none":
            y = ref.act_fn(a)(y)
        outs.append(y.astype(out_dtype) if out_dtype is not None else y)
        off += nn
    return tuple(outs)


def linear_ep(x, w, bias, act: str, ep, residual, cfg: EngineConfig, *,
              res_scale: float = 1.0, out_scale=None,
              out_dtype=jnp.float32) -> jax.Array:
    """LinearOp with a fused epilogue: the residual add after an O/down
    projection rides the Conv PE launch (passes.fuse_epilogues on LM
    graphs never attaches pool tails to LinearOps).

    Pallas int8/int4 paths stream the residual into the kernel's NL core
    (ep.mid_scale re-quantizes the GEMM output at its pre-fusion edge
    scale); ref / baseline / float paths compose the identical chain math
    on the GEMM output (_epilogue.fused_chain, the bit-exact oracle).
    """
    static = isinstance(x, QTensor)
    quanted = ((isinstance(w, QTensor) and cfg.quant in _INT8_ACTS)
               or (isinstance(w, Q4Tensor) and cfg.quant == "w4a8"))
    pallas = (cfg.backend == "pallas" and not cfg.baseline and quanted
              and ep.pool == "none")
    if pallas:
        return linear(x, w, bias, act, cfg, out_dtype=out_dtype,
                      out_scale=out_scale, residual=residual,
                      res_scale=res_scale,
                      mid_scale=(ep.mid_scale if static and ep.mid_scale
                                 else None),
                      add_act=ep.add_act)
    y = linear(x, w, bias, act, cfg, out_dtype=jnp.float32)
    return _epilogue.fused_chain(
        y, residual=residual, res_scale=res_scale,
        **_chain_kwargs(ep, static and quanted, out_scale))


# ---------------------------------------------------------------------------
# Conv2D via Conv PE (im2col -> GEMM), the CNN standard-conv path
# ---------------------------------------------------------------------------

def conv2d_pe(x, w, bias: Optional[jax.Array],
              stride: int, padding: str, act: str,
              cfg: EngineConfig, out_dtype=jnp.float32,
              out_scale: Optional[float] = None,
              epilogue=None,
              residual: Optional[jax.Array] = None,
              res_scale: float = 1.0) -> jax.Array:
    """Standard conv: x [N,H,W,IC] float or QTensor (static int8 activations
    with a per-tensor scale); w [k,k,IC,OC] float or QTensor, or the
    compile-time-folded GEMM layout [k*k*IC, OC]
    (passes.fold_weight_layouts).

    Float x under a quant mode quantizes activations dynamically per-image;
    QTensor x skips that round-trip (the compiled engine-program path).  The
    conv lowers to the Conv PE GEMM with K = k*k*IC (the paper's IC-cascade
    contraction); out_scale requants to int8 in the fused NL epilogue.
    SAME zero-padding is exact for int8 inputs (symmetric quant, zero
    point 0).

    `epilogue` (a graph.Epilogue from passes.fuse_epilogues) runs the
    absorbed MISC tail -- residual add (`residual` raw values at
    `res_scale`), activation, avg/global/max pool, requant -- inside the
    SAME launch on the pallas backend (kernel second operand / pooled
    accumulation); the ref and baseline backends compose the identical
    chain math on the GEMM output (the bit-exact oracle).
    """
    static = isinstance(x, QTensor)
    if static and not isinstance(w, QTensor):
        x = x.dequant()                       # float weights: float math
        static = False
    xv = x.q if static else x
    wq = w.q if isinstance(w, QTensor) else w
    ic = xv.shape[-1]
    if wq.ndim == 2:
        # Pre-laid-out GEMM weights [k*k*IC, OC] (passes.fold_weight_layouts
        # ran the im2col reshape at compile time); recover the window size.
        oc = wq.shape[1]
        k = round((wq.shape[0] // ic) ** 0.5)
        if k * k * ic != wq.shape[0]:
            raise ValueError(
                f"folded conv weight K={wq.shape[0]} does not factor as "
                f"k*k*IC for IC={ic}")
        wmat = wq
    else:
        k = wq.shape[0]
        oc = wq.shape[3]
        wmat = wq.reshape(k * k * ic, oc)
    if padding == "SAME":
        ph = _same_pad(xv.shape[1], k, stride)
        pw = _same_pad(xv.shape[2], k, stride)
        xv = jnp.pad(xv, ((0, 0), ph, pw, (0, 0)))
    n, hp, wp, _ = xv.shape
    ho = (hp - k) // stride + 1
    wo = (wp - k) // stride + 1
    # im2col: [N*HO*WO, k*k*IC]
    patches = []
    for kh in range(k):
        for kw in range(k):
            xs = jax.lax.slice(
                xv, (0, kh, kw, 0),
                (n, kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1, ic),
                (1, stride, stride, 1))
            patches.append(xs)
    col = jnp.concatenate(patches, axis=-1).reshape(n * ho * wo, k * k * ic)
    if isinstance(w, QTensor):
        wt = QTensor(wmat, w.scale.reshape(1, oc))
        col_in = QTensor(col, x.scale) if static else col
        if epilogue is not None:
            return _conv_epilogue(col_in, wt, bias, act, epilogue, residual,
                                  res_scale, out_scale, cfg, out_dtype,
                                  n, ho, wo, oc)
        out = linear(col_in, wt, bias, act, cfg, out_dtype=out_dtype,
                     out_scale=out_scale)
    else:
        if out_scale is not None:
            raise ValueError("out_scale requires QTensor weights")
        out = linear_f(col, wmat, bias, act, cfg, out_dtype=out_dtype)
        if epilogue is not None:
            return _epilogue.fused_chain(
                out.reshape(n, ho, wo, oc), residual=residual,
                res_scale=res_scale,
                **_chain_kwargs(epilogue, False, None))
    return out.reshape(n, ho, wo, oc)


def _conv_epilogue(col_in, wt: QTensor, bias, act: str, ep, residual,
                   res_scale: float, out_scale, cfg: EngineConfig,
                   out_dtype, n: int, ho: int, wo: int, oc: int) -> jax.Array:
    """Fused Conv PE epilogue dispatch (quantized GEMM path)."""
    static = isinstance(col_in, QTensor)
    pallas = (cfg.backend == "pallas" and not cfg.baseline
              and cfg.quant in _INT8_ACTS)
    if pallas and ep.pool == "none":
        # residual second operand streams into the GEMM kernel's NL core
        out = linear(col_in, wt, bias, act, cfg, out_dtype=out_dtype,
                     out_scale=out_scale,
                     residual=residual.reshape(n * ho * wo, oc),
                     res_scale=res_scale,
                     mid_scale=ep.mid_scale if static and ep.mid_scale
                     else None,
                     add_act=ep.add_act)
        return out.reshape(n, ho, wo, oc)
    if pallas:
        return _conv_pool_pallas(col_in, wt, bias, act, ep, residual,
                                 res_scale, out_scale, cfg, out_dtype,
                                 n, ho, wo, oc)
    # ref / baseline: the GEMM part (f32, pre-requant) + the shared
    # in-register chain math -- XLA fuses it; bit-exact vs the unfused ops
    y = linear(col_in, wt, bias, act, cfg, out_dtype=jnp.float32)
    return _epilogue.fused_chain(y.reshape(n, ho, wo, oc),
                                 residual=residual, res_scale=res_scale,
                                 **_chain_kwargs(ep, static, out_scale))


def _conv_pool_pallas(col_in, wt: QTensor, bias, act: str, ep, residual,
                      res_scale: float, out_scale, cfg: EngineConfig,
                      out_dtype, n: int, ho: int, wo: int, oc: int):
    """Pooled-epilogue launch: per-image M blocking so the avg/global/max
    tail accumulates in-kernel (conv_pe.matmul_int8_pool)."""
    static = isinstance(col_in, QTensor)
    rows = ho * wo
    kdim = (col_in.q if static else col_in).shape[-1]
    if static:
        colq = col_in.q
        asc = jnp.full((n, rows, 1), float(col_in.scale), jnp.float32)
    else:
        xq = quantize_act_dynamic(col_in, per_token=True)
        colq, asc = xq.q, xq.scale.reshape(n, rows, 1)
    _, bn, bk = pick_blocks(rows, oc, kdim, 1, cfg)
    rows_p = _round_up(rows, 32)
    kp, np_ = _round_up(kdim, bk), _round_up(oc, bn)
    a3 = jnp.pad(colq.reshape(n, rows, kdim),
                 ((0, 0), (0, rows_p - rows), (0, kp - kdim)))
    asc3 = jnp.pad(asc, ((0, 0), (0, rows_p - rows), (0, 0)))
    bq = _pad2d(wt.q, kp, np_)
    wsc = jnp.pad(wt.scale.reshape(1, oc), ((0, 0), (0, np_ - oc)))
    b = (jnp.pad(bias.astype(jnp.float32), (0, np_ - oc))
         if bias is not None else None)
    r3 = None
    if residual is not None:
        r3 = jnp.pad(residual.reshape(n, rows, oc),
                     ((0, 0), (0, rows_p - rows), (0, np_ - oc)))
    if out_scale is not None and not isinstance(out_scale, (int, float)):
        raise ValueError("pooled epilogues requant per-tensor")
    out = conv_pe.matmul_int8_pool(
        a3, bq, asc3, wsc, b, act, ho=ho, wo=wo, residual=r3,
        res_scale=res_scale,
        mid_scale=ep.mid_scale if static and ep.mid_scale else None,
        add_act=ep.add_act,
        add_scale=ep.add_scale if static and ep.add_scale else None,
        pool=ep.pool, pool_kernel=ep.pool_kernel, pool_stride=ep.pool_stride,
        out_scale=out_scale if static else None, out_dtype=out_dtype,
        bn=bn, bk=bk, interpret=cfg.interpret)
    pho, pwo = _epilogue.pooled_hw(ho, wo, ep.pool, ep.pool_kernel,
                                   ep.pool_stride)
    out = out[:, :pho * pwo, :oc]
    if ep.pool == "global":
        return out.reshape(n, oc)
    return out.reshape(n, pho, pwo, oc)


def _same_pad(size: int, k: int, stride: int):
    out = -(-size // stride)
    pad = max((out - 1) * stride + k - size, 0)
    return (pad // 2, pad - pad // 2)


# ---------------------------------------------------------------------------
# DWC PE
# ---------------------------------------------------------------------------

def dwc2d(x, w, bias: Optional[jax.Array], stride: int,
          padding: str, act: str, cfg: EngineConfig,
          out_dtype=jnp.float32,
          out_scale: Optional[float] = None,
          epilogue=None,
          residual: Optional[jax.Array] = None,
          res_scale: float = 1.0) -> jax.Array:
    """Depthwise conv. x [N,H,W,C] float or QTensor (static int8 with a
    per-tensor scale); w [k,k,C] float or QTensor, possibly pre-padded to
    [k,k,round_up(C,128)] by passes.fold_weight_layouts (bias and scales
    padded alongside).  out_scale requants to int8 in the RACNL epilogue.

    `epilogue` fuses an absorbed MISC tail (residual add / pool / requant)
    into the RACNL core -- in-kernel on the pallas DWC engine, composed
    chain math elsewhere (see conv2d_pe).

    Without the DWC engine (baseline), this runs as the paper's "low
    utilization" path: dense GEMM with a channel-diagonal weight matrix.
    """
    static = isinstance(x, QTensor)
    is_q = isinstance(w, QTensor)
    if static and not is_q:
        x = x.dequant()               # float weights: float math
        static = False
    wq = w.q if is_q else w
    k = wq.shape[0]
    c = (x.q if static else x).shape[-1]
    cw = wq.shape[2]
    prepadded = cw != c
    if prepadded:
        if cw != _round_up(c, 128):
            raise ValueError(f"dwc weight channels {cw} match neither C={c} "
                             f"nor the 128-lane padded width")
        if not cfg.use_dwc_engine:
            # the dense-diagonal baseline works on true channels; un-pad
            wq = wq[:, :, :c]
            if is_q:
                w = QTensor(wq, w.scale[..., :c])
            else:
                w = wq
            if bias is not None:
                bias = bias[:c]
            prepadded = False
    if not cfg.use_dwc_engine:
        # Baseline (no DWC engine).  A grouped conv with group-count ==
        # channels is exactly a per-channel depthwise conv, so lower it
        # through the depthwise taps directly instead of materializing the
        # [k, k, C, C] channel-diagonal weight matrix and running a full
        # C**2 GEMM -- the old lowering burned O(C) compute and memory for
        # identical values (adding the off-diagonal zeros is IEEE-exact).
        # Static int8 inputs still pay the full dequant/requant round-trip
        # here -- exactly the cost the DWC engine's fused epilogue avoids.
        if static:
            x = x.dequant()
        if padding == "SAME":
            ph = _same_pad(x.shape[1], k, stride)
            pw = _same_pad(x.shape[2], k, stride)
            x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
        wf = (w.dequant() if is_q else wq).astype(jnp.float32)
        out = ref.dwc2d(x.astype(jnp.float32), wf, bias, stride, act,
                        out_dtype=jnp.float32)
        if epilogue is not None:
            return _epilogue.fused_chain(
                out, residual=residual, res_scale=res_scale,
                **_chain_kwargs(epilogue, static, out_scale))
        if out_scale is not None:
            return quantize_static(out, jnp.float32(out_scale))
        return out.astype(out_dtype)

    quant = (is_q and cfg.quant in _INT8_ACTS) or static
    if quant:
        if static:
            xin = x.q
            # per-tensor float scale, or a per-channel [C] vector (the
            # channelwise engine dequantizes each lane at its own scale)
            a_scale = (float(x.scale) if jnp.ndim(x.scale) == 0
                       else jnp.asarray(x.scale, jnp.float32))
        else:
            xq = quantize_act_dynamic(x, per_token=False)
            a_scale = xq.scale
            xin = xq.q
        w_scale = w.scale.reshape(-1)
        w_in = w.q
    else:
        xin = x
        w_in = w.dequant(x.dtype) if is_q else w
        a_scale = w_scale = None
    if padding == "SAME":
        ph = _same_pad(xin.shape[1], k, stride)
        pw = _same_pad(xin.shape[2], k, stride)
        xin = jnp.pad(xin, ((0, 0), ph, pw, (0, 0)))

    cp = _round_up(c, 128)
    bc = min(128, cp)
    if cp != c:  # lane alignment: the paper's zero-padded weights
        xin = jnp.pad(xin, ((0, 0), (0, 0), (0, 0), (0, cp - c)))
        if not prepadded:   # else weights/bias/scales were folded at compile
            w_in = jnp.pad(w_in, ((0, 0), (0, 0), (0, cp - c)))
            if bias is not None:
                bias = jnp.pad(bias, (0, cp - c))
            if w_scale is not None:
                w_scale = jnp.pad(w_scale, (0, cp - c))
        if a_scale is not None and jnp.ndim(a_scale) == 1:
            # per-channel activation scales pad alongside the lanes
            a_scale = jnp.pad(a_scale, (0, cp - c), constant_values=1.0)

    if epilogue is not None:
        ep = epilogue
        if cfg.backend == "pallas":
            rin = residual
            if rin is not None and cp != c:
                rin = jnp.pad(rin, ((0, 0), (0, 0), (0, 0), (0, cp - c)))
            out = dwc_pe.dwc2d(
                xin, w_in, bias, stride, act,
                a_scale=a_scale if quant else None, w_scale=w_scale,
                out_scale=out_scale if static else None, out_dtype=out_dtype,
                residual=rin, res_scale=res_scale,
                mid_scale=ep.mid_scale if static and ep.mid_scale else None,
                add_act=ep.add_act,
                add_scale=ep.add_scale if static and ep.add_scale else None,
                pool=ep.pool, pool_kernel=ep.pool_kernel,
                pool_stride=ep.pool_stride, bc=bc, interpret=cfg.interpret)
        else:
            y = ref.dwc2d(xin, w_in, bias, stride, act,
                          a_scale=a_scale if quant else None,
                          w_scale=w_scale, out_dtype=jnp.float32)
            rin = residual
            if rin is not None and cp != c:
                rin = jnp.pad(rin, ((0, 0), (0, 0), (0, 0), (0, cp - c)))
            out = _epilogue.fused_chain(y, residual=rin, res_scale=res_scale,
                                        **_chain_kwargs(ep, static, out_scale))
        out = out[..., :c]
        if ep.pool == "global" and out.ndim == 4:
            out = out.reshape(out.shape[0], c)    # [N,1,1,C] -> [N,C]
        return out

    if cfg.backend == "pallas":
        out = dwc_pe.dwc2d(xin, w_in, bias, stride, act,
                           a_scale=a_scale if quant else None,
                           w_scale=w_scale, out_scale=out_scale,
                           out_dtype=out_dtype,
                           bc=bc, interpret=cfg.interpret)
    else:
        out = ref.dwc2d(xin, w_in, bias, stride, act,
                        a_scale=a_scale if quant else None,
                        w_scale=w_scale, out_scale=out_scale,
                        out_dtype=out_dtype)
    return out[..., :c]


def dwc1d_causal(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
                 act: str, cfg: EngineConfig) -> jax.Array:
    """Causal temporal depthwise conv. x [B,L,C] float, w [k,C]."""
    c = x.shape[-1]
    cp = _round_up(c, 128)
    if cfg.backend == "pallas" and cfg.use_dwc_engine:
        xin = jnp.pad(x, ((0, 0), (0, 0), (0, cp - c))) if cp != c else x
        w_in = jnp.pad(w, ((0, 0), (0, cp - c))) if cp != c else w
        b_in = (jnp.pad(bias, (0, cp - c)) if (bias is not None and cp != c)
                else bias)
        out = dwc_pe.dwc1d_causal(xin, w_in, b_in, act, out_dtype=x.dtype,
                                  bc=min(128, cp), interpret=cfg.interpret)
        return out[..., :c]
    return ref.dwc1d_causal(x, w, bias, act, out_dtype=x.dtype)


# ---------------------------------------------------------------------------
# Low-Channel Conv Unit
# ---------------------------------------------------------------------------

def first_layer_conv(x, w, bias: Optional[jax.Array],
                     stride: int, padding: str, act: str,
                     cfg: EngineConfig, out_dtype=jnp.float32,
                     out_scale: Optional[float] = None,
                     epilogue=None,
                     residual: Optional[jax.Array] = None,
                     res_scale: float = 1.0) -> jax.Array:
    """Stage-0 conv. Dispatches to the low-channel unit when enabled,
    otherwise to the general Conv PE (the paper's 13.1%-utilization path).

    x may be a QTensor (the compiled program quantizes the input image with
    the calibrated static scale); out_scale requants the stem output to int8
    so the whole engine pipeline stays int8 from the first layer on.

    `epilogue` fuses an absorbed pool tail (the stem -> max-pool chain)
    into the unit's epilogue; residual adds never fuse into the stem
    (fuse_epilogues does not create them).
    """
    static = isinstance(x, QTensor)
    if not cfg.use_low_channel_unit:
        return conv2d_pe(x, w, bias, stride, padding, act, cfg,
                         out_dtype=out_dtype, out_scale=out_scale,
                         epilogue=epilogue, residual=residual,
                         res_scale=res_scale)
    if epilogue is not None and epilogue.add:
        raise ValueError("the Low-Channel unit fuses pool tails only")
    is_q = isinstance(w, QTensor)
    if static and not is_q:
        x = x.dequant()               # float weights: float math
        static = False
    wq = w.q if is_q else w
    k = wq.shape[0]
    quant = (is_q and cfg.quant in _INT8_ACTS) or static
    if quant:
        if static:
            xin, a_scale = x.q, float(x.scale)   # compile-time constant
        else:
            xq = quantize_act_dynamic(x, per_token=False)
            xin, a_scale = xq.q, xq.scale        # traced scalar (jit-safe)
        w_in = w.q
        w_scale = w.scale.reshape(-1)       # per-output-channel [OC]
    else:
        xin = x                     # static was cleared by the fallback above
        w_in = w.dequant(xin.dtype) if is_q else w
        a_scale = w_scale = None
    if padding == "SAME":
        ph = _same_pad(xin.shape[1], k, stride)
        pw = _same_pad(xin.shape[2], k, stride)
        xin = jnp.pad(xin, ((0, 0), ph, pw, (0, 0)))
    if epilogue is not None:
        ep = epilogue
        if cfg.backend == "pallas":
            out = low_channel.low_channel_conv(
                xin, w_in, bias, stride, act, a_scale=a_scale,
                w_scale=w_scale,
                out_scale=out_scale if static else None, out_dtype=out_dtype,
                mid_scale=ep.mid_scale if static and ep.mid_scale else None,
                pool=ep.pool, pool_kernel=ep.pool_kernel,
                pool_stride=ep.pool_stride, interpret=cfg.interpret)
        else:
            y = ref.low_channel_conv(xin, w_in, bias, stride, act,
                                     a_scale=a_scale, w_scale=w_scale,
                                     out_dtype=jnp.float32)
            out = _epilogue.fused_chain(y, **_chain_kwargs(ep, static,
                                                           out_scale))
        if ep.pool == "global" and out.ndim == 4:
            out = out.reshape(out.shape[0], out.shape[-1])
        return out
    if cfg.backend == "pallas":
        return low_channel.low_channel_conv(
            xin, w_in, bias, stride, act, a_scale=a_scale, w_scale=w_scale,
            out_scale=out_scale, out_dtype=out_dtype, interpret=cfg.interpret)
    return ref.low_channel_conv(xin, w_in, bias, stride, act,
                                a_scale=a_scale, w_scale=w_scale,
                                out_scale=out_scale, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# MISC core
# ---------------------------------------------------------------------------

def misc_add(a: jax.Array, b: jax.Array, act: str, cfg: EngineConfig,
             sa: float = 1.0, sb: float = 1.0,
             out_dtype=jnp.float32,
             out_scale: Optional[float] = None) -> jax.Array:
    """Residual add.  In the compiled int8 program a/b are int8 and sa/sb are
    their static edge scales; out_scale requants the sum in the same pass."""
    if not cfg.misc_on_engine:
        # Baseline: separate ops (paper: PL DSP adders).
        x = jax.lax.optimization_barrier(
            a.astype(jnp.float32) * sa + b.astype(jnp.float32) * sb)
        x = ref.act_fn(act)(x)
        if out_scale is not None:
            return quantize_static(x, jnp.float32(out_scale))
        return x.astype(out_dtype)
    if cfg.backend == "pallas":
        return misc_pe.misc_add(a, b, sa, sb, act, out_scale=out_scale,
                                out_dtype=out_dtype,
                                interpret=cfg.interpret)
    return ref.misc_add(a, b, sa, sb, act, out_scale=out_scale,
                        out_dtype=out_dtype)


def avgpool2d(x: jax.Array, window: int, stride: int, cfg: EngineConfig,
              out_dtype=jnp.float32) -> jax.Array:
    c = x.shape[-1]
    if cfg.misc_on_engine and cfg.backend == "pallas" and c % 128 == 0:
        return misc_pe.avgpool2d(x, window, stride, out_dtype=out_dtype,
                                 interpret=cfg.interpret)
    return ref.avgpool2d(x, window, stride, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Flash attention (Pallas prefill kernel) -- beyond-paper
# ---------------------------------------------------------------------------

def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, softcap: float = 0.0,
              cfg: Optional[EngineConfig] = None) -> jax.Array:
    """q: [B, H, L, D]; k, v: [B, H, S, D] (same head count: the caller
    repeats or groups GQA heads).  Pads L/S to block multiples."""
    from repro.kernels import flash_attn
    cfg = cfg or EngineConfig(backend="pallas", interpret=True)
    b, h, l, d = q.shape
    s = k.shape[2]
    bq = bkv = 128
    lp, sp = _round_up(l, bq), _round_up(s, bkv)
    qf = jnp.pad(q.reshape(b * h, l, d), ((0, 0), (0, lp - l), (0, 0)))
    kf = jnp.pad(k.reshape(b * h, s, d), ((0, 0), (0, sp - s), (0, 0)))
    vf = jnp.pad(v.reshape(b * h, s, d), ((0, 0), (0, sp - s), (0, 0)))
    if cfg.backend == "pallas":
        # padded queries attend to nothing real; slice them off below
        out = flash_attn.flash_attention(
            qf, kf, vf, causal=causal, softcap=softcap,
            scale=d ** -0.5, bq=bq, bkv=bkv, interpret=cfg.interpret)
    else:
        out = ref.attention(qf[:, None].transpose(1, 0, 2, 3), kf[:, None
                            ].transpose(1, 0, 2, 3), vf[:, None].transpose(
                            1, 0, 2, 3), causal=causal,
                            logit_softcap=softcap)[0]
    return out[:, :l].reshape(b, h, l, d)


# ---------------------------------------------------------------------------
# Paged KV cache gather -- beyond-paper (LM serving)
# ---------------------------------------------------------------------------

def paged_gather(pool: jax.Array, tables: jax.Array,
                 cfg: EngineConfig) -> jax.Array:
    """Gather a block-paged KV pool [N, P, ...] into the slot-ordered dense
    view [B, M*P, ...] a dense cache would hold, via block table [B, M].

    Table entries are clipped into [0, N-1] HERE, once, for both backends:
    unallocated pages carry the positive sentinel N (negative sentinels
    would WRAP under JAX gather), and the Pallas index_map cannot take an
    out-of-range block id.  Whatever a clipped sentinel reads sits at
    positions >= the slot's length and is masked to -inf downstream, so the
    two backends stay bitwise identical.
    """
    tables = jnp.clip(tables, 0, pool.shape[0] - 1)
    if cfg.backend == "pallas" and not cfg.baseline:
        from repro.kernels import flash_attn
        return flash_attn.paged_gather(pool, tables,
                                       interpret=cfg.interpret)
    return ref.paged_gather(pool, tables)
