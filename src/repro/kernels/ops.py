"""Public kernel wrappers: backend selection, padding, DSE-chosen blocks.

Every op has three backends:
  * "ref"     -- pure-jnp oracle (kernels/ref.py).  CPU execution and the
                 dry-run lowering use this path.
  * "pallas"  -- the Pallas TPU kernel (interpret=True on this CPU container).
  * baseline  -- the XVDPU-analog unfused path (ref.matmul_int8_unfused).

Wrappers own all shape legalization: flattening leading dims, padding M/N/K
to block multiples (the paper's bank-alignment / zero-padding steps), and
channel padding to the 128-lane width for the DWC engine.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import dse
from repro.core.config import EngineConfig
from repro.core.quant import QTensor, quantize_act_dynamic
from repro.kernels import conv_pe, dwc_pe, low_channel, misc_pe, ref


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def _pad2d(x: jax.Array, m: int, n: int) -> jax.Array:
    pm, pn = m - x.shape[0], n - x.shape[1]
    if pm == 0 and pn == 0:
        return x
    return jnp.pad(x, ((0, pm), (0, pn)))


def pick_blocks(m: int, n: int, k: int, in_bytes: int,
                cfg: EngineConfig):
    """Block shapes: explicit config overrides, else the DSE solver."""
    if cfg.block_m and cfg.block_n and cfg.cascade_bk:
        return cfg.block_m, cfg.block_n, cfg.cascade_bk
    t = dse.solve_conv_blocks(m, n, k, in_dtype_bytes=in_bytes)
    bm = min(t.bm, _round_up(m, 128))
    bn = min(t.bn, _round_up(n, 128))
    bk = min(t.bk, _round_up(k, 128))
    return bm, bn, bk


# ---------------------------------------------------------------------------
# Conv PE: quantized linear (the LM projection / 1x1-conv path)
# ---------------------------------------------------------------------------

def linear_int8(x: jax.Array, w: QTensor, bias: Optional[jax.Array],
                act: str, cfg: EngineConfig,
                out_dtype=jnp.float32) -> jax.Array:
    """x: float [..., K]; w: QTensor(q=[K, N] int8, scale=[1, N])."""
    lead = x.shape[:-1]
    kdim = x.shape[-1]
    n = w.q.shape[-1]
    m = 1
    for d in lead:
        m *= d
    x2 = x.reshape(m, kdim)
    xq = quantize_act_dynamic(x2, per_token=True)          # a_scale [M, 1]
    w_scale = w.scale.reshape(1, n)

    if cfg.baseline:
        out = ref.matmul_int8_unfused(xq.q, w.q, xq.scale, w_scale, bias, act,
                                      out_dtype=out_dtype)
    elif cfg.backend == "pallas":
        bm, bn, bk = pick_blocks(m, n, kdim, 1, cfg)
        mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(kdim, bk)
        aq = _pad2d(xq.q, mp, kp)
        bq = _pad2d(w.q, kp, np_)
        asc = jnp.pad(xq.scale, ((0, mp - m), (0, 0)))
        wsc = jnp.pad(w_scale, ((0, 0), (0, np_ - n)))
        b = (jnp.pad(bias.astype(jnp.float32), (0, np_ - n))
             if bias is not None else None)
        out = conv_pe.matmul_int8_fused(
            aq, bq, asc, wsc, b, act, out_dtype=out_dtype,
            bm=bm, bn=bn, bk=bk, interpret=cfg.interpret)[:m, :n]
    else:
        out = ref.matmul_int8_fused(xq.q, w.q, xq.scale, w_scale, bias, act,
                                    out_dtype=out_dtype)
    return out.reshape(*lead, n)


def linear_w8(x: jax.Array, w: QTensor, bias: Optional[jax.Array],
              act: str, cfg: EngineConfig, out_dtype=jnp.float32) -> jax.Array:
    """Weight-only int8: dequantize weights, bf16 MAC (memory-bound decode)."""
    wf = w.dequant(x.dtype)
    out = jnp.dot(x, wf)
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return ref.act_fn(act)(out).astype(out_dtype)


def linear_f(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
             act: str, cfg: EngineConfig, out_dtype=None) -> jax.Array:
    """Float path (training)."""
    out_dtype = out_dtype or x.dtype
    out = jnp.dot(x, w.astype(x.dtype))
    if bias is not None:
        out = out + bias.astype(out.dtype)
    return ref.act_fn(act)(out).astype(out_dtype)


def linear(x: jax.Array, w, bias, act: str, cfg: EngineConfig,
           out_dtype=None) -> jax.Array:
    """Dispatch on quant mode and weight container type."""
    if isinstance(w, QTensor):
        if cfg.quant == "w8a8":
            return linear_int8(x, w, bias, act, cfg,
                               out_dtype=out_dtype or jnp.float32)
        return linear_w8(x, w, bias, act, cfg,
                         out_dtype=out_dtype or x.dtype)
    return linear_f(x, w, bias, act, cfg, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Conv2D via Conv PE (im2col -> GEMM), the CNN standard-conv path
# ---------------------------------------------------------------------------

def conv2d_pe(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
              stride: int, padding: str, act: str,
              cfg: EngineConfig, out_dtype=jnp.float32) -> jax.Array:
    """Standard conv: x [N,H,W,IC] float, w [k,k,IC,OC] float or QTensor.

    Quant modes quantize activations dynamically per-image; the conv lowers
    to the Conv PE GEMM with K = k*k*IC (the paper's IC-cascade contraction).
    """
    wq = w.q if isinstance(w, QTensor) else w
    k = wq.shape[0]
    ic, oc = wq.shape[2], wq.shape[3]
    if padding == "SAME":
        ph = _same_pad(x.shape[1], k, stride)
        pw = _same_pad(x.shape[2], k, stride)
        x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    n, hp, wp, _ = x.shape
    ho = (hp - k) // stride + 1
    wo = (wp - k) // stride + 1
    # im2col: [N*HO*WO, k*k*IC]
    patches = []
    for kh in range(k):
        for kw in range(k):
            xs = jax.lax.slice(
                x, (0, kh, kw, 0),
                (n, kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1, ic),
                (1, stride, stride, 1))
            patches.append(xs)
    col = jnp.concatenate(patches, axis=-1).reshape(n * ho * wo, k * k * ic)
    wmat = wq.reshape(k * k * ic, oc)
    if isinstance(w, QTensor):
        wt = QTensor(wmat, w.scale.reshape(1, oc))
        out = linear(col, wt, bias, act, cfg, out_dtype=out_dtype)
    else:
        out = linear_f(col, wmat, bias, act, cfg, out_dtype=out_dtype)
    return out.reshape(n, ho, wo, oc)


def _same_pad(size: int, k: int, stride: int):
    out = -(-size // stride)
    pad = max((out - 1) * stride + k - size, 0)
    return (pad // 2, pad - pad // 2)


# ---------------------------------------------------------------------------
# DWC PE
# ---------------------------------------------------------------------------

def dwc2d(x: jax.Array, w, bias: Optional[jax.Array], stride: int,
          padding: str, act: str, cfg: EngineConfig,
          out_dtype=jnp.float32) -> jax.Array:
    """Depthwise conv. x [N,H,W,C] float; w [k,k,C] float or QTensor.

    Without the DWC engine (baseline), this runs as the paper's "low
    utilization" path: dense GEMM with a channel-diagonal weight matrix.
    """
    is_q = isinstance(w, QTensor)
    wq = w.q if is_q else w
    k = wq.shape[0]
    c = wq.shape[2]
    if padding == "SAME":
        ph = _same_pad(x.shape[1], k, stride)
        pw = _same_pad(x.shape[2], k, stride)
        x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))

    if not cfg.use_dwc_engine:
        # Baseline: depthwise as dense conv with diagonalized weights
        # (one input channel per group lowered to a full GEMM -- wasteful by
        # construction, like running DWC on the Conv PE).
        wf = w.dequant() if is_q else wq
        dense = jnp.zeros((k, k, c, c), jnp.float32)
        idx = jnp.arange(c)
        dense = dense.at[:, :, idx, idx].set(wf.astype(jnp.float32))
        return conv2d_pe(x, dense, bias, stride, "VALID", act,
                         cfg, out_dtype=out_dtype)

    quant = is_q and cfg.quant == "w8a8"
    if quant:
        xq = quantize_act_dynamic(x, per_token=False)
        a_scale = xq.scale
        xin = xq.q
        w_scale = w.scale.reshape(-1)
        w_in = w.q
    else:
        xin = x
        w_in = w.dequant(x.dtype) if is_q else w
        a_scale = w_scale = None

    cp = _round_up(c, 128)
    bc = min(128, cp)
    if cp != c:  # lane alignment: the paper's zero-padded weights
        xin = jnp.pad(xin, ((0, 0), (0, 0), (0, 0), (0, cp - c)))
        w_in = jnp.pad(w_in, ((0, 0), (0, 0), (0, cp - c)))
        if bias is not None:
            bias = jnp.pad(bias, (0, cp - c))
        if w_scale is not None:
            w_scale = jnp.pad(w_scale, (0, cp - c))

    if cfg.backend == "pallas":
        out = dwc_pe.dwc2d(xin, w_in, bias, stride, act,
                           a_scale=(float(a_scale) if quant else None),
                           w_scale=w_scale, out_dtype=out_dtype,
                           bc=bc, interpret=cfg.interpret)
    else:
        out = ref.dwc2d(xin, w_in, bias, stride, act,
                        a_scale=a_scale if quant else None,
                        w_scale=w_scale, out_dtype=out_dtype)
    return out[..., :c]


def dwc1d_causal(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
                 act: str, cfg: EngineConfig) -> jax.Array:
    """Causal temporal depthwise conv. x [B,L,C] float, w [k,C]."""
    c = x.shape[-1]
    cp = _round_up(c, 128)
    if cfg.backend == "pallas" and cfg.use_dwc_engine:
        xin = jnp.pad(x, ((0, 0), (0, 0), (0, cp - c))) if cp != c else x
        w_in = jnp.pad(w, ((0, 0), (0, cp - c))) if cp != c else w
        b_in = (jnp.pad(bias, (0, cp - c)) if (bias is not None and cp != c)
                else bias)
        out = dwc_pe.dwc1d_causal(xin, w_in, b_in, act, out_dtype=x.dtype,
                                  bc=min(128, cp), interpret=cfg.interpret)
        return out[..., :c]
    return ref.dwc1d_causal(x, w, bias, act, out_dtype=x.dtype)


# ---------------------------------------------------------------------------
# Low-Channel Conv Unit
# ---------------------------------------------------------------------------

def first_layer_conv(x: jax.Array, w, bias: Optional[jax.Array],
                     stride: int, padding: str, act: str,
                     cfg: EngineConfig, out_dtype=jnp.float32) -> jax.Array:
    """Stage-0 conv. Dispatches to the low-channel unit when enabled,
    otherwise to the general Conv PE (the paper's 13.1%-utilization path)."""
    if not cfg.use_low_channel_unit:
        return conv2d_pe(x, w, bias, stride, padding, act, cfg,
                         out_dtype=out_dtype)
    is_q = isinstance(w, QTensor)
    wq = w.q if is_q else w
    k = wq.shape[0]
    if padding == "SAME":
        ph = _same_pad(x.shape[1], k, stride)
        pw = _same_pad(x.shape[2], k, stride)
        x = jnp.pad(x, ((0, 0), ph, pw, (0, 0)))
    quant = is_q and cfg.quant == "w8a8"
    if quant:
        xq = quantize_act_dynamic(x, per_token=False)
        xin, a_scale = xq.q, float(xq.scale)
        w_in = w.q
        w_scale = float(jnp.max(w.scale))   # per-tensor for the small unit
    else:
        xin = x
        w_in = w.dequant(x.dtype) if is_q else w
        a_scale = w_scale = None
    if cfg.backend == "pallas":
        return low_channel.low_channel_conv(
            xin, w_in, bias, stride, act, a_scale=a_scale, w_scale=w_scale,
            out_dtype=out_dtype, interpret=cfg.interpret)
    return ref.low_channel_conv(xin, w_in, bias, stride, act,
                                a_scale=a_scale, w_scale=w_scale,
                                out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# MISC core
# ---------------------------------------------------------------------------

def misc_add(a: jax.Array, b: jax.Array, act: str, cfg: EngineConfig,
             sa: float = 1.0, sb: float = 1.0,
             out_dtype=jnp.float32) -> jax.Array:
    if not cfg.misc_on_engine:
        # Baseline: separate ops (paper: PL DSP adders).
        x = jax.lax.optimization_barrier(
            a.astype(jnp.float32) * sa + b.astype(jnp.float32) * sb)
        return ref.act_fn(act)(x).astype(out_dtype)
    if cfg.backend == "pallas":
        return misc_pe.misc_add(a, b, sa, sb, act, out_dtype=out_dtype,
                                interpret=cfg.interpret)
    return ref.misc_add(a, b, sa, sb, act, out_dtype=out_dtype)


def avgpool2d(x: jax.Array, window: int, stride: int, cfg: EngineConfig,
              out_dtype=jnp.float32) -> jax.Array:
    c = x.shape[-1]
    if cfg.misc_on_engine and cfg.backend == "pallas" and c % 128 == 0:
        return misc_pe.avgpool2d(x, window, stride, out_dtype=out_dtype,
                                 interpret=cfg.interpret)
    return ref.avgpool2d(x, window, stride, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Flash attention (Pallas prefill kernel) -- beyond-paper
# ---------------------------------------------------------------------------

def flash_mha(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, softcap: float = 0.0,
              cfg: Optional[EngineConfig] = None) -> jax.Array:
    """q: [B, H, L, D]; k, v: [B, H, S, D] (same head count: the caller
    repeats or groups GQA heads).  Pads L/S to block multiples."""
    from repro.kernels import flash_attn
    cfg = cfg or EngineConfig(backend="pallas", interpret=True)
    b, h, l, d = q.shape
    s = k.shape[2]
    bq = bkv = 128
    lp, sp = _round_up(l, bq), _round_up(s, bkv)
    qf = jnp.pad(q.reshape(b * h, l, d), ((0, 0), (0, lp - l), (0, 0)))
    kf = jnp.pad(k.reshape(b * h, s, d), ((0, 0), (0, sp - s), (0, 0)))
    vf = jnp.pad(v.reshape(b * h, s, d), ((0, 0), (0, sp - s), (0, 0)))
    if cfg.backend == "pallas":
        # padded queries attend to nothing real; slice them off below
        out = flash_attn.flash_attention(
            qf, kf, vf, causal=causal, softcap=softcap,
            scale=d ** -0.5, bq=bq, bkv=bkv, interpret=cfg.interpret)
    else:
        out = ref.attention(qf[:, None].transpose(1, 0, 2, 3), kf[:, None
                            ].transpose(1, 0, 2, 3), vf[:, None].transpose(
                            1, 0, 2, 3), causal=causal,
                            logit_softcap=softcap)[0]
    return out[:, :l].reshape(b, h, l, d)
