"""Conv PE: int8 GEMM with cascade K-accumulation and a fused NL epilogue.

TPU adaptation of the paper's MAC->ACC->NL chain (Section IV-B, Fig. 3-4):

  * The MAC chain's in-flight cascade accumulation over IC becomes the K grid
    axis with a revolving int32 VMEM accumulator (`acc_ref`): partial sums
    live in VMEM for the whole reduction and never round-trip HBM -- exactly
    the property the cascade stream buys on the AIE array.
  * The ACC core's PsumStack is `acc_ref` (BM*BN*4 B); its bank budget
    (paper Eq. 3-4) is the VMEM constraint solved by core/dse.py.
  * The NL core is the fused epilogue on the last K step: dequant (per-token
    activation scale x per-channel weight scale), bias add, activation,
    optional requantization to int8.
  * Pallas's double-buffered software pipeline plays the role of the paper's
    ping-pong buffers and bubble-elimination protocol (Fig. 5): the grid is
    declared ("parallel", "parallel", "arbitrary") so the K walk is a clean
    revolving pipeline with no inter-step stalls after warmup.

Block shapes default to the DSE solver's choice (core/dse.solve_conv_blocks),
mirroring how the paper derives OC=32 / IH*IW=64 from Table I.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.ref import act_fn
from repro.kernels import _epilogue
from repro.kernels._pallas_compat import compiler_params


def _kernel(a_ref, b_ref, a_scale_ref, w_scale_ref, bias_ref, os_ref, o_ref,
            acc_ref, *, nk: int, act: str, has_bias: bool,
            out_scale: Optional[float], vector_os: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MAC chain link: one cascade step of the IC reduction.
    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.int32),
                            b_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    # NL core: fused epilogue once the cascade completes.
    @pl.when(k == nk - 1)
    def _epilogue():
        x = acc_ref[...].astype(jnp.float32)
        x = x * a_scale_ref[...] * w_scale_ref[...]
        if has_bias:
            x = x + bias_ref[...]
        x = act_fn(act)(x)
        if vector_os:
            # per-output-channel requant (e.g. a per-channel edge feeding
            # the channelwise DWC engine): the divisor streams in blocked
            # [1, bn] like the weight scales.
            x = jnp.clip(jnp.round(x / os_ref[...]), -127, 127)
        elif out_scale is not None:
            x = jnp.clip(jnp.round(x / out_scale), -127, 127)
        o_ref[...] = x.astype(o_ref.dtype)


def _kernel_res(a_ref, b_ref, a_scale_ref, w_scale_ref, bias_ref, os_ref,
                r_ref, o_ref, acc_ref, *, nk: int, act: str, has_bias: bool,
                out_scale: Optional[float], vector_os: bool,
                mid_scale: Optional[float], res_scale: float, add_act: str):
    """The residual-epilogue variant: a second input operand streams into
    the NL core and the absorbed MISC add runs in-register after the
    cascade -- the fused conv->add(->act) chain as ONE launch."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.int32),
                            b_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _tail():
        x = acc_ref[...].astype(jnp.float32)
        x = x * a_scale_ref[...] * w_scale_ref[...]
        if has_bias:
            x = x + bias_ref[...]
        x = act_fn(act)(x)
        if mid_scale is not None:
            # in-register requant to the absorbed conv edge's static scale
            # (what the unfused program materialized): bit-identical values
            x = jnp.clip(jnp.round(x / mid_scale), -127.0, 127.0) * mid_scale
        x = x + r_ref[...].astype(jnp.float32) * res_scale
        x = act_fn(add_act)(x)
        if vector_os:
            x = jnp.clip(jnp.round(x / os_ref[...]), -127, 127)
        elif out_scale is not None:
            x = jnp.clip(jnp.round(x / out_scale), -127, 127)
        o_ref[...] = x.astype(o_ref.dtype)


def matmul_int8_fused(a_q: jax.Array, b_q: jax.Array,
                      a_scale: jax.Array, w_scale: jax.Array,
                      bias: Optional[jax.Array] = None,
                      act: str = "none",
                      out_scale=None,
                      out_dtype=jnp.float32,
                      *,
                      residual: Optional[jax.Array] = None,
                      res_scale: float = 1.0,
                      mid_scale: Optional[float] = None,
                      add_act: str = "none",
                      bm: int = 128, bn: int = 128, bk: int = 512,
                      interpret: bool = False) -> jax.Array:
    """Fused int8 GEMM. Shapes must be multiples of the block shapes
    (kernels/ops.py pads).  a_q [M,K] int8, b_q [K,N] int8,
    a_scale [M,1] f32, w_scale [1,N] f32, bias [N] f32 or None.
    out_scale: None (float out), a scalar (per-tensor int8 requant), or a
    [N]-broadcastable array (per-output-channel requant, pre-padded).

    residual [M,N] (int8 with `res_scale`, or f32) adds the fused-epilogue
    second operand: the absorbed residual add + `add_act` run in-register
    after the cascade (`mid_scale`: the static scale of the absorbed conv
    edge; None on the dynamic path).
    """
    m, kdim = a_q.shape
    _, n = b_q.shape
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (m, n, kdim, bm, bn, bk)
    nk = kdim // bk
    has_bias = bias is not None
    bias2d = (bias.reshape(1, n).astype(jnp.float32) if has_bias
              else jnp.zeros((1, n), jnp.float32))
    vector_os = out_scale is not None and not isinstance(
        out_scale, (int, float))
    os2d = (jnp.asarray(out_scale, jnp.float32).reshape(1, n) if vector_os
            else jnp.ones((1, n), jnp.float32))
    odt = jnp.int8 if out_scale is not None else out_dtype

    grid = (m // bm, n // bn, nk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),     # A
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),     # B
        pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),       # a_scale
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),       # w_scale
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),       # bias
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),       # out_scale
    ]
    operands = [a_q, b_q, a_scale.astype(jnp.float32).reshape(m, 1),
                w_scale.astype(jnp.float32).reshape(1, n), bias2d, os2d]
    if residual is None:
        kernel = functools.partial(
            _kernel, nk=nk, act=act, has_bias=has_bias,
            out_scale=None if vector_os else out_scale, vector_os=vector_os)
    else:
        assert residual.shape == (m, n), (residual.shape, m, n)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)))
        operands.append(residual)
        kernel = functools.partial(
            _kernel_res, nk=nk, act=act, has_bias=has_bias,
            out_scale=None if vector_os else out_scale, vector_os=vector_os,
            mid_scale=mid_scale, res_scale=res_scale, add_act=add_act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), odt),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],         # PsumStack
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# Int4 weight-only variant (XEGEMM_INT4 idiom): the weight operand streams
# PACKED (two nibbles per byte along K, per-group scale + zero), halving the
# GEMM's weight bytes; the kernel unpacks and dequantizes IN-REGISTER.  The
# per-group int32 partial sums stay exact (ref.int4_group_dot is the value
# oracle); one combine + NL epilogue per (bm, bn) block.  K is not gridded:
# the decode GEMMs this path serves have small reduction dims, and a whole-K
# block keeps the group reduction inside one kernel instance.
# ---------------------------------------------------------------------------

def _kernel_w4(a_ref, b_ref, wsc_ref, wz_ref, a_scale_ref, bias_ref, os_ref,
               o_ref, *, act: str, has_bias: bool,
               out_scale: Optional[float], vector_os: bool):
    from repro.core.quant import unpack_int4
    from repro.kernels.ref import int4_group_dot

    codes = unpack_int4(b_ref[...])                     # [K, bn] in-register
    x = int4_group_dot(a_ref[...], codes, wsc_ref[...], wz_ref[...])
    x = x * a_scale_ref[...]
    if has_bias:
        x = x + bias_ref[...]
    x = act_fn(act)(x)
    if vector_os:
        x = jnp.clip(jnp.round(x / os_ref[...]), -127, 127)
    elif out_scale is not None:
        x = jnp.clip(jnp.round(x / out_scale), -127, 127)
    o_ref[...] = x.astype(o_ref.dtype)


def _kernel_w4_res(a_ref, b_ref, wsc_ref, wz_ref, a_scale_ref, bias_ref,
                   os_ref, r_ref, o_ref, *, act: str, has_bias: bool,
                   out_scale: Optional[float], vector_os: bool,
                   mid_scale: Optional[float], res_scale: float,
                   add_act: str):
    """Residual-epilogue variant: the absorbed MISC add after an O/down
    projection rides the same in-register-dequant launch."""
    from repro.core.quant import unpack_int4
    from repro.kernels.ref import int4_group_dot

    codes = unpack_int4(b_ref[...])
    x = int4_group_dot(a_ref[...], codes, wsc_ref[...], wz_ref[...])
    x = x * a_scale_ref[...]
    if has_bias:
        x = x + bias_ref[...]
    x = act_fn(act)(x)
    if mid_scale is not None:
        x = jnp.clip(jnp.round(x / mid_scale), -127.0, 127.0) * mid_scale
    x = x + r_ref[...].astype(jnp.float32) * res_scale
    x = act_fn(add_act)(x)
    if vector_os:
        x = jnp.clip(jnp.round(x / os_ref[...]), -127, 127)
    elif out_scale is not None:
        x = jnp.clip(jnp.round(x / out_scale), -127, 127)
    o_ref[...] = x.astype(o_ref.dtype)


def matmul_int4_fused(a_q: jax.Array, b_packed: jax.Array,
                      a_scale: jax.Array, w_scale: jax.Array,
                      w_zero: jax.Array,
                      bias: Optional[jax.Array] = None,
                      act: str = "none",
                      out_scale=None,
                      out_dtype=jnp.float32,
                      *,
                      residual: Optional[jax.Array] = None,
                      res_scale: float = 1.0,
                      mid_scale: Optional[float] = None,
                      add_act: str = "none",
                      bm: int = 128, bn: int = 128,
                      interpret: bool = False) -> jax.Array:
    """Fused int4 weight-only GEMM: a_q [M, K] int8 x b_packed [K//2, N]
    uint8 nibble pairs, w_scale/w_zero [G, N] per-group (K = G * gs).
    M and N must be multiples of the block shapes (kernels/ops.py pads);
    the group dim pads in whole groups with zero scale/zero.  Epilogue and
    residual contract match matmul_int8_fused.
    """
    m, kdim = a_q.shape
    k2, n = b_packed.shape
    g = w_scale.shape[0]
    assert kdim == 2 * k2 and kdim % g == 0, (kdim, k2, g)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    has_bias = bias is not None
    bias2d = (bias.reshape(1, n).astype(jnp.float32) if has_bias
              else jnp.zeros((1, n), jnp.float32))
    vector_os = out_scale is not None and not isinstance(
        out_scale, (int, float))
    os2d = (jnp.asarray(out_scale, jnp.float32).reshape(1, n) if vector_os
            else jnp.ones((1, n), jnp.float32))
    odt = jnp.int8 if out_scale is not None else out_dtype

    grid = (m // bm, n // bn)
    in_specs = [
        pl.BlockSpec((bm, kdim), lambda i, j: (i, 0)),        # A (whole K)
        pl.BlockSpec((k2, bn), lambda i, j: (0, j)),          # B packed
        pl.BlockSpec((g, bn), lambda i, j: (0, j)),           # group scales
        pl.BlockSpec((g, bn), lambda i, j: (0, j)),           # group zeros
        pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),           # a_scale
        pl.BlockSpec((1, bn), lambda i, j: (0, j)),           # bias
        pl.BlockSpec((1, bn), lambda i, j: (0, j)),           # out_scale
    ]
    operands = [a_q, b_packed, w_scale, w_zero,
                a_scale.astype(jnp.float32).reshape(m, 1), bias2d, os2d]
    if residual is None:
        kernel = functools.partial(
            _kernel_w4, act=act, has_bias=has_bias,
            out_scale=None if vector_os else out_scale, vector_os=vector_os)
    else:
        assert residual.shape == (m, n), (residual.shape, m, n)
        in_specs.append(pl.BlockSpec((bm, bn), lambda i, j: (i, j)))
        operands.append(residual)
        kernel = functools.partial(
            _kernel_w4_res, act=act, has_bias=has_bias,
            out_scale=None if vector_os else out_scale, vector_os=vector_os,
            mid_scale=mid_scale, res_scale=res_scale, add_act=add_act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), odt),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# Pooled-epilogue variant: per-image M blocking so the absorbed avg/global/
# max pool tail accumulates in-kernel (the GAP tail never materializes the
# pre-pool feature map)
# ---------------------------------------------------------------------------

def _kernel_pool(*refs, nk: int, act: str, has_bias: bool, has_res: bool,
                 rows: int, ho: int, wo: int, out_rows: int,
                 mid_scale: Optional[float], res_scale: float, add_act: str,
                 add_scale: Optional[float], pool: str, pool_kernel: int,
                 pool_stride: int, out_scale: Optional[float]):
    if has_res:
        (a_ref, b_ref, asc_ref, wsc_ref, bias_ref, r_ref,
         o_ref, acc_ref) = refs
    else:
        a_ref, b_ref, asc_ref, wsc_ref, bias_ref, o_ref, acc_ref = refs
        r_ref = None
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[0].astype(jnp.int32),
                            b_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    @pl.when(k == nk - 1)
    def _tail():
        x = acc_ref[...].astype(jnp.float32)        # [rows_p, bn]
        x = x * asc_ref[0] * wsc_ref[...]
        if has_bias:
            x = x + bias_ref[...]
        x = act_fn(act)(x)
        bn = x.shape[-1]
        xs = x[:rows].reshape(ho, wo, bn)           # valid rows only
        rs = (r_ref[0][:rows].reshape(ho, wo, bn) if has_res else None)
        y = _epilogue.fused_chain(
            xs, mid_scale=mid_scale, residual=rs, res_scale=res_scale,
            add_act=add_act, add_scale=add_scale, pool=pool,
            pool_kernel=pool_kernel, pool_stride=pool_stride,
            out_scale=out_scale)
        y = y.reshape(-1, bn)
        y = jnp.pad(y, ((0, out_rows - y.shape[0]), (0, 0)))
        o_ref[0] = y.astype(o_ref.dtype)


def matmul_int8_pool(a_q: jax.Array, b_q: jax.Array, a_scale: jax.Array,
                     w_scale: jax.Array, bias: Optional[jax.Array],
                     act: str, *, ho: int, wo: int,
                     residual: Optional[jax.Array] = None,
                     res_scale: float = 1.0,
                     mid_scale: Optional[float] = None,
                     add_act: str = "none",
                     add_scale: Optional[float] = None,
                     pool: str = "global", pool_kernel: int = 0,
                     pool_stride: int = 0,
                     out_scale: Optional[float] = None,
                     out_dtype=jnp.float32,
                     bn: int = 128, bk: int = 512,
                     interpret: bool = False) -> jax.Array:
    """Fused GEMM + pooled epilogue, ONE launch per program.

    a_q [G, rows_p, K] int8: the im2col rows blocked per image (G = batch;
    rows_p >= ho*wo, padded); b_q [K, N]; a_scale [G, rows_p, 1];
    w_scale [1, N]; residual [G, rows_p, N] or None.  The epilogue slices
    the valid ho*wo rows, runs the fused chain (qdq/add/act), and POOLS
    in-register before the single write-out, so the pre-pool feature map
    never reaches memory.  Returns [G, out_rows, N] where out_rows rows 0..
    pooled_h*pooled_w-1 are valid (caller slices + reshapes).

    VMEM note: the accumulator holds the image's full [rows_p, bn] tile --
    sized for the tail-of-network feature maps where pool chains live.
    """
    g, rows_p, kdim = a_q.shape
    _, n = b_q.shape
    assert n % bn == 0 and kdim % bk == 0, (n, kdim, bn, bk)
    nk = kdim // bk
    rows = ho * wo
    assert rows <= rows_p, (rows, rows_p)
    pho, pwo = _epilogue.pooled_hw(ho, wo, pool, pool_kernel, pool_stride)
    out_rows = max(8, -(-(pho * pwo) // 8) * 8)
    has_bias = bias is not None
    bias2d = (bias.reshape(1, n).astype(jnp.float32) if has_bias
              else jnp.zeros((1, n), jnp.float32))
    odt = _epilogue.chain_out_dtype(mid_scale, pool, out_scale, out_dtype)

    in_specs = [
        pl.BlockSpec((1, rows_p, bk), lambda i, j, kk: (i, 0, kk)),   # A
        pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),             # B
        pl.BlockSpec((1, rows_p, 1), lambda i, j, kk: (i, 0, 0)),     # asc
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),               # wsc
        pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),               # bias
    ]
    operands = [a_q, b_q, a_scale.astype(jnp.float32),
                w_scale.astype(jnp.float32).reshape(1, n), bias2d]
    if residual is not None:
        assert residual.shape == (g, rows_p, n), (residual.shape, g, rows_p, n)
        in_specs.append(
            pl.BlockSpec((1, rows_p, bn), lambda i, j, kk: (i, 0, j)))
        operands.append(residual)
    return pl.pallas_call(
        functools.partial(
            _kernel_pool, nk=nk, act=act, has_bias=has_bias,
            has_res=residual is not None, rows=rows, ho=ho, wo=wo,
            out_rows=out_rows, mid_scale=mid_scale, res_scale=res_scale,
            add_act=add_act, add_scale=add_scale, pool=pool,
            pool_kernel=pool_kernel, pool_stride=pool_stride,
            out_scale=out_scale),
        grid=(g, n // bn, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, out_rows, bn), lambda i, j, kk: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((g, out_rows, n), odt),
        scratch_shapes=[pltpu.VMEM((rows_p, bn), jnp.int32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# bf16 variant (training-path GEMM with fused epilogue; same dataflow)
# ---------------------------------------------------------------------------

def _kernel_f(a_ref, b_ref, bias_ref, o_ref, acc_ref,
              *, nk: int, act: str, has_bias: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        x = acc_ref[...]
        if has_bias:
            x = x + bias_ref[...]
        o_ref[...] = act_fn(act)(x).astype(o_ref.dtype)


def matmul_f_fused(a: jax.Array, b: jax.Array,
                   bias: Optional[jax.Array] = None, act: str = "none",
                   out_dtype=jnp.float32, *,
                   bm: int = 128, bn: int = 128, bk: int = 512,
                   interpret: bool = False) -> jax.Array:
    m, kdim = a.shape
    _, n = b.shape
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    nk = kdim // bk
    has_bias = bias is not None
    bias2d = (bias.reshape(1, n).astype(jnp.float32) if has_bias
              else jnp.zeros((1, n), jnp.float32))
    return pl.pallas_call(
        functools.partial(_kernel_f, nk=nk, act=act, has_bias=has_bias),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, bias2d)
