"""Conv PE: int8 GEMM with cascade K-accumulation and a fused NL epilogue.

TPU adaptation of the paper's MAC->ACC->NL chain (Section IV-B, Fig. 3-4):

  * The MAC chain's in-flight cascade accumulation over IC becomes the K grid
    axis with a revolving int32 VMEM accumulator (`acc_ref`): partial sums
    live in VMEM for the whole reduction and never round-trip HBM -- exactly
    the property the cascade stream buys on the AIE array.
  * The ACC core's PsumStack is `acc_ref` (BM*BN*4 B); its bank budget
    (paper Eq. 3-4) is the VMEM constraint solved by core/dse.py.
  * The NL core is the fused epilogue on the last K step: dequant (per-token
    activation scale x per-channel weight scale), bias add, activation,
    optional requantization to int8.
  * Pallas's double-buffered software pipeline plays the role of the paper's
    ping-pong buffers and bubble-elimination protocol (Fig. 5): the grid is
    declared ("parallel", "parallel", "arbitrary") so the K walk is a clean
    revolving pipeline with no inter-step stalls after warmup.

Block shapes default to the DSE solver's choice (core/dse.solve_conv_blocks),
mirroring how the paper derives OC=32 / IH*IW=64 from Table I.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

from repro.kernels.ref import act_fn
from repro.kernels._pallas_compat import compiler_params


def _kernel(a_ref, b_ref, a_scale_ref, w_scale_ref, bias_ref, os_ref, o_ref,
            acc_ref, *, nk: int, act: str, has_bias: bool,
            out_scale: Optional[float], vector_os: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MAC chain link: one cascade step of the IC reduction.
    acc_ref[...] += jnp.dot(a_ref[...].astype(jnp.int32),
                            b_ref[...].astype(jnp.int32),
                            preferred_element_type=jnp.int32)

    # NL core: fused epilogue once the cascade completes.
    @pl.when(k == nk - 1)
    def _epilogue():
        x = acc_ref[...].astype(jnp.float32)
        x = x * a_scale_ref[...] * w_scale_ref[...]
        if has_bias:
            x = x + bias_ref[...]
        x = act_fn(act)(x)
        if vector_os:
            # per-output-channel requant (e.g. a per-channel edge feeding
            # the channelwise DWC engine): the divisor streams in blocked
            # [1, bn] like the weight scales.
            x = jnp.clip(jnp.round(x / os_ref[...]), -127, 127)
        elif out_scale is not None:
            x = jnp.clip(jnp.round(x / out_scale), -127, 127)
        o_ref[...] = x.astype(o_ref.dtype)


def matmul_int8_fused(a_q: jax.Array, b_q: jax.Array,
                      a_scale: jax.Array, w_scale: jax.Array,
                      bias: Optional[jax.Array] = None,
                      act: str = "none",
                      out_scale=None,
                      out_dtype=jnp.float32,
                      *,
                      bm: int = 128, bn: int = 128, bk: int = 512,
                      interpret: bool = False) -> jax.Array:
    """Fused int8 GEMM. Shapes must be multiples of the block shapes
    (kernels/ops.py pads).  a_q [M,K] int8, b_q [K,N] int8,
    a_scale [M,1] f32, w_scale [1,N] f32, bias [N] f32 or None.
    out_scale: None (float out), a scalar (per-tensor int8 requant), or a
    [N]-broadcastable array (per-output-channel requant, pre-padded).
    """
    m, kdim = a_q.shape
    _, n = b_q.shape
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0, (m, n, kdim, bm, bn, bk)
    nk = kdim // bk
    has_bias = bias is not None
    bias2d = (bias.reshape(1, n).astype(jnp.float32) if has_bias
              else jnp.zeros((1, n), jnp.float32))
    vector_os = out_scale is not None and not isinstance(
        out_scale, (int, float))
    os2d = (jnp.asarray(out_scale, jnp.float32).reshape(1, n) if vector_os
            else jnp.ones((1, n), jnp.float32))
    odt = jnp.int8 if out_scale is not None else out_dtype

    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_kernel, nk=nk, act=act, has_bias=has_bias,
                          out_scale=None if vector_os else out_scale,
                          vector_os=vector_os),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),     # A
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),     # B
            pl.BlockSpec((bm, 1), lambda i, j, kk: (i, 0)),       # a_scale
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),       # w_scale
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),       # bias
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),       # out_scale
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), odt),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],         # PsumStack
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a_q, b_q, a_scale.astype(jnp.float32).reshape(m, 1),
      w_scale.astype(jnp.float32).reshape(1, n), bias2d, os2d)


# ---------------------------------------------------------------------------
# bf16 variant (training-path GEMM with fused epilogue; same dataflow)
# ---------------------------------------------------------------------------

def _kernel_f(a_ref, b_ref, bias_ref, o_ref, acc_ref,
              *, nk: int, act: str, has_bias: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        x = acc_ref[...]
        if has_bias:
            x = x + bias_ref[...]
        o_ref[...] = act_fn(act)(x).astype(o_ref.dtype)


def matmul_f_fused(a: jax.Array, b: jax.Array,
                   bias: Optional[jax.Array] = None, act: str = "none",
                   out_dtype=jnp.float32, *,
                   bm: int = 128, bn: int = 128, bk: int = 512,
                   interpret: bool = False) -> jax.Array:
    m, kdim = a.shape
    _, n = b.shape
    assert m % bm == 0 and n % bn == 0 and kdim % bk == 0
    nk = kdim // bk
    has_bias = bias is not None
    bias2d = (bias.reshape(1, n).astype(jnp.float32) if has_bias
              else jnp.zeros((1, n), jnp.float32))
    return pl.pallas_call(
        functools.partial(_kernel_f, nk=nk, act=act, has_bias=has_bias),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b, bias2d)
