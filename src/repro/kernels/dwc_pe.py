"""DWC PE: depthwise-convolution engine.

TPU adaptation of the paper's DWC PE (Section IV-C, Fig. 6-8):

  * The paper's problem: depthwise conv has no IC reduction, so the MAC
    cascade is useless and fmap sharing across kernels is impossible.  Their
    answer: tile the feature map per core, keep the *channel* dimension on the
    16-lane vector unit, zero-pad weights to bank alignment, and fuse
    accumulate+NL in the RACNL core.
  * TPU mapping: channels ride the 128-wide lane dimension of the VPU (the
    16-lane AIE vector analogue), the spatial tile lives in sublanes, the
    kernel taps are unrolled as aligned strided loads from a VMEM-resident
    input tile (loaded ONCE per (batch, channel-block) -- the data-reuse the
    paper engineers with its atomic-DWC schedule), and bias/act/requant are
    fused in the epilogue (RACNL core).
  * The paper's weight zero-padding for bank alignment maps to channel
    padding to a multiple of 128 lanes (done by the ops.py wrapper).

Grid: (N, C/BC); each cell owns the full (pre-padded) spatial extent, so no
halo exchange is needed -- the analogue of each MAC core owning a full fmap
tile plus kernel apron.

A 1-D causal variant (dwc1d) serves the mamba / RG-LRU temporal conv and is
the same engine with H=1 semantics.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ref import act_fn
from repro.kernels import _epilogue
from repro.kernels._pallas_compat import compiler_params


def _dwc2d_kernel(*refs, k: int, stride: int, ho: int, wo: int, act: str,
                  quant: bool, out_scale: Optional[float], has_res: bool,
                  mid_scale: Optional[float], res_scale: float, add_act: str,
                  add_scale: Optional[float], pool: str, pool_kernel: int,
                  pool_stride: int):
    if has_res:
        x_ref, w_ref, bias_ref, wscale_ref, r_ref, o_ref = refs
    else:
        x_ref, w_ref, bias_ref, wscale_ref, o_ref = refs
        r_ref = None
    x = x_ref[0]                       # [Hp, Wp, BC]
    acc_dtype = jnp.int32 if quant else jnp.float32
    acc = jnp.zeros((ho, wo, x.shape[-1]), acc_dtype)
    for kh in range(k):                # unrolled taps: the atomic-DWC schedule
        for kw in range(k):
            xs = jax.lax.slice(
                x, (kh, kw, 0),
                (kh + (ho - 1) * stride + 1, kw + (wo - 1) * stride + 1,
                 x.shape[-1]),
                (stride, stride, 1))
            acc = acc + xs.astype(acc_dtype) * w_ref[kh, kw, :].astype(acc_dtype)
    xf = acc.astype(jnp.float32)
    if quant:
        xf = xf * wscale_ref[0, 0, :]
    xf = xf + bias_ref[0, 0, :]
    xf = act_fn(act)(xf)
    if has_res or pool != "none":
        # fused MISC tail: the RACNL core absorbs the residual add / pool
        y = _epilogue.fused_chain(
            xf, mid_scale=mid_scale, residual=r_ref[0] if has_res else None,
            res_scale=res_scale, add_act=add_act, add_scale=add_scale,
            pool=pool, pool_kernel=pool_kernel, pool_stride=pool_stride,
            out_scale=out_scale)
        if pool == "global":
            y = y.reshape(1, 1, -1)
        o_ref[0] = y.astype(o_ref.dtype)
        return
    if out_scale is not None:
        xf = jnp.clip(jnp.round(xf / out_scale), -127, 127)
    o_ref[0] = xf.astype(o_ref.dtype)


def dwc2d(x: jax.Array, w: jax.Array, bias: Optional[jax.Array],
          stride: int = 1, act: str = "none",
          a_scale: Optional[float] = None,
          w_scale: Optional[jax.Array] = None,
          out_scale: Optional[float] = None,
          out_dtype=jnp.float32, *,
          residual: Optional[jax.Array] = None,
          res_scale: float = 1.0,
          mid_scale: Optional[float] = None,
          add_act: str = "none",
          add_scale: Optional[float] = None,
          pool: str = "none", pool_kernel: int = 0, pool_stride: int = 0,
          bc: int = 128, interpret: bool = False) -> jax.Array:
    """Depthwise conv on pre-padded input (VALID). x: [N, Hp, Wp, C] with
    C % bc == 0; w: [k, k, C]; bias: [C].

    residual [N, Ho, Wo, C] (int8 with `res_scale`, or f32) and/or
    pool ("avg" | "global" | "max") fuse the absorbed MISC tail into the
    RACNL epilogue: one launch, no intermediate feature map.  mid_scale /
    add_scale are the static interior requant points (None = dynamic f32
    chain).  With a pool tail the output is [N, PHo, PWo, C].
    """
    n, hp, wp, c = x.shape
    k = w.shape[0]
    assert c % bc == 0, (c, bc)
    ho = (hp - k) // stride + 1
    wo = (wp - k) // stride + 1
    quant = a_scale is not None
    # Fold the (scalar per-tensor) activation scale into the per-channel
    # weight scale so the epilogue is one multiply -- the RACNL requant.
    # a_scale may be a Python float (static programs) or a traced scalar
    # (dynamic quantization under jit).
    wsc = (jnp.asarray(w_scale, jnp.float32).reshape(1, 1, c)
           * jnp.asarray(a_scale, jnp.float32)
           if quant else jnp.zeros((1, 1, c), jnp.float32))
    bias_arr = (bias.astype(jnp.float32).reshape(1, 1, c) if bias is not None
                else jnp.zeros((1, 1, c), jnp.float32))
    pho, pwo = _epilogue.pooled_hw(ho, wo, pool, pool_kernel, pool_stride)
    if residual is not None or pool != "none":
        odt = _epilogue.chain_out_dtype(mid_scale, pool, out_scale, out_dtype)
    else:
        odt = jnp.int8 if out_scale is not None else out_dtype

    in_specs = [
        pl.BlockSpec((1, hp, wp, bc), lambda i, j: (i, 0, 0, j)),
        pl.BlockSpec((k, k, bc), lambda i, j: (0, 0, j)),
        pl.BlockSpec((1, 1, bc), lambda i, j: (0, 0, j)),
        pl.BlockSpec((1, 1, bc), lambda i, j: (0, 0, j)),
    ]
    operands = [x, w, bias_arr, wsc]
    if residual is not None:
        assert residual.shape == (n, ho, wo, c), (residual.shape, n, ho, wo, c)
        in_specs.append(pl.BlockSpec((1, ho, wo, bc), lambda i, j: (i, 0, 0, j)))
        operands.append(residual)
    return pl.pallas_call(
        functools.partial(
            _dwc2d_kernel, k=k, stride=stride, ho=ho, wo=wo, act=act,
            quant=quant, out_scale=out_scale, has_res=residual is not None,
            mid_scale=mid_scale, res_scale=res_scale, add_act=add_act,
            add_scale=add_scale, pool=pool, pool_kernel=pool_kernel,
            pool_stride=pool_stride),
        grid=(n, c // bc),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, pho, pwo, bc), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, pho, pwo, c), odt),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# 1-D causal variant (mamba / RG-LRU temporal conv)
# ---------------------------------------------------------------------------

def _dwc1d_kernel(x_ref, w_ref, bias_ref, o_ref, *, k: int, l: int, act: str):
    x = x_ref[0]                       # [L + k - 1, BC]
    acc = jnp.zeros((l, x.shape[-1]), jnp.float32)
    for i in range(k):
        acc = acc + x[i:i + l, :].astype(jnp.float32) * w_ref[i, :].astype(jnp.float32)
    acc = acc + bias_ref[0, :]
    o_ref[0] = act_fn(act)(acc).astype(o_ref.dtype)


def dwc1d_causal(x: jax.Array, w: jax.Array,
                 bias: Optional[jax.Array] = None, act: str = "none",
                 out_dtype=jnp.float32, *,
                 bc: int = 128, interpret: bool = False) -> jax.Array:
    """x: [B, L, C] (C % bc == 0), w: [k, C], bias: [C]."""
    b, l, c = x.shape
    k = w.shape[0]
    assert c % bc == 0, (c, bc)
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    bias_arr = (bias.astype(jnp.float32).reshape(1, c) if bias is not None
                else jnp.zeros((1, c), jnp.float32))
    return pl.pallas_call(
        functools.partial(_dwc1d_kernel, k=k, l=l, act=act),
        grid=(b, c // bc),
        in_specs=[
            pl.BlockSpec((1, l + k - 1, bc), lambda i, j: (i, 0, j)),
            pl.BlockSpec((k, bc), lambda i, j: (0, j)),
            pl.BlockSpec((1, bc), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, l, bc), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, l, c), out_dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(xp, w, bias_arr)
