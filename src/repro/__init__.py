"""repro: DPUV4E-on-TPU -- an INT8 engine-centric JAX training/serving framework."""
__version__ = "0.1.0"
