"""Render EXPERIMENTS.md tables from the dry-run / hillclimb artifacts.

    PYTHONPATH=src python experiments/report.py
"""
import glob
import json
import os
import sys

DRY = os.path.join(os.path.dirname(__file__), "dryrun")
PERF = os.path.join(os.path.dirname(__file__), "perf")
EXP = os.path.join(os.path.dirname(__file__), "..", "EXPERIMENTS.md")

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_t(s):
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.1f}ms"
    return f"{s * 1e6:.0f}us"


def roofline_table():
    recs = {}
    for path in glob.glob(os.path.join(DRY, "*.json")):
        r = json.load(open(path))
        recs[(r["mesh"], r["arch"], r["shape"])] = r
    lines = []
    # Single-pod: the full roofline table (assignment: roofline is
    # single-pod only).
    sub = {(a, s): r for (m, a, s), r in recs.items() if m == "pod16x16"}
    if sub:
        lines.append("\n### Mesh `pod16x16` (256 chips) — roofline baselines\n")
        lines.append("| arch × shape | compute | memory | collective | "
                     "bound | useful | roofline | GB/dev | status |")
        lines.append("|---|---|---|---|---|---|---|---|---|")
        for a in sorted({a for a, _ in sub}):
            for s in SHAPE_ORDER:
                r = sub.get((a, s))
                if r is None:
                    continue
                if r["status"] == "skipped":
                    lines.append(f"| {a} × {s} | — | — | — | — | — | — | — | "
                                 f"skip (O(L²) @500k) |")
                    continue
                if r["status"] != "ok":
                    lines.append(f"| {a} × {s} | — | — | — | — | — | — | — | "
                                 f"ERROR {r['error'][:60]} |")
                    continue
                lines.append(
                    f"| {a} × {s} | {_fmt_t(r['t_compute_s'])} | "
                    f"{_fmt_t(r['t_memory_s'])} | "
                    f"{_fmt_t(r['t_collective_s'])} | "
                    f"{r['bottleneck']} | {r['useful_flop_ratio']:.2f} | "
                    f"{100 * r['roofline_fraction']:.1f}% | "
                    f"{r['bytes_per_device'] / 2**30:.1f} | ok |")
    # Multi-pod: the compile-pass table (proves the pod axis shards).
    sub = {(a, s): r for (m, a, s), r in recs.items() if m == "pod2x16x16"}
    if sub:
        lines.append("\n### Mesh `pod2x16x16` (512 chips) — multi-pod "
                     "compile pass\n")
        lines.append("| arch × shape | compiled | GB/dev | compile time |")
        lines.append("|---|---|---|---|")
        for a in sorted({a for a, _ in sub}):
            for s in SHAPE_ORDER:
                r = sub.get((a, s))
                if r is None:
                    continue
                if r["status"] == "skipped":
                    lines.append(f"| {a} × {s} | skip (O(L²) @500k) | — | — |")
                elif r["status"] != "ok":
                    lines.append(f"| {a} × {s} | **ERROR** "
                                 f"{r['error'][:60]} | — | — |")
                else:
                    lines.append(
                        f"| {a} × {s} | yes | "
                        f"{r['bytes_per_device'] / 2**30:.1f} | "
                        f"{r['compile_s']:.0f}s |")
    ok = sum(1 for r in recs.values() if r["status"] == "ok")
    sk = sum(1 for r in recs.values() if r["status"] == "skipped")
    er = sum(1 for r in recs.values() if r["status"] == "error")
    lines.append(f"\n**Totals: {ok} compiled ok, {sk} skipped (assignment "
                 f"rule), {er} errors.**\n")
    return "\n".join(lines)


def perf_table():
    paths = sorted(glob.glob(os.path.join(PERF, "*.json")))
    if not paths:
        return "(hillclimb artifacts not yet generated)"
    by_cell = {}
    for p in paths:
        r = json.load(open(p))
        cell = os.path.basename(p).split("__")[0]
        by_cell.setdefault(cell, []).append(r)
    lines = []
    for cell, rs in by_cell.items():
        rs.sort(key=lambda r: r.get("variant", ""))
        lines.append(f"\n### {cell}: {rs[0]['arch']} × {rs[0]['shape']}\n")
        lines.append("| variant | hypothesis | compute | memory | "
                     "collective | bound | roofline |")
        lines.append("|---|---|---|---|---|---|---|")
        for r in rs:
            if r["status"] != "ok":
                lines.append(f"| {r.get('variant')} | {r.get('hypothesis', '')[:60]} "
                             f"| — | — | — | ERROR | — |")
                continue
            lines.append(
                f"| {r['variant']} | {r['hypothesis'][:70]}… | "
                f"{_fmt_t(r['t_compute_s'])} | {_fmt_t(r['t_memory_s'])} | "
                f"{_fmt_t(r['t_collective_s'])} | {r['bottleneck']} | "
                f"{100 * r['roofline_fraction']:.2f}% |")
    return "\n".join(lines)


def _splice(text: str, marker: str, content: str) -> str:
    """Replace everything between `marker` and the next '## ' heading."""
    if marker not in text:
        return text
    head, _, tail = text.partition(marker)
    idx = tail.find("\n## ")
    rest = tail[idx:] if idx >= 0 else "\n"
    return head + marker + "\n" + content + "\n" + rest


def main():
    with open(EXP) as f:
        text = f.read()
    text = _splice(text, "<!-- ROOFLINE_TABLE -->", roofline_table())
    text = _splice(text, "<!-- PERF_LOG -->", perf_table())
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
